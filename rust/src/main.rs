//! `repro` — ScaDLES leader entrypoint.
//!
//! Subcommands:
//! * `train` — run one configurable training job (ScaDLES or DDL).
//! * `exp <id>` — regenerate a paper table/figure (DESIGN.md §4).
//! * `serve` / `join` — the multi-process localhost demo: a TCP
//!   coordinator hub plus worker processes speaking the runtime's
//!   rendezvous/heartbeat/witness protocol.
//! * `info` — inspect the compiled artifact manifest.
//! * `list` — list experiment ids.
//!
//! The CLI parser is hand-rolled (the sandbox builds fully offline, so no
//! clap); flags are `--name value` or `--flag`.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context};
use scadles::buffer::BufferPolicy;
use scadles::config::{
    CompressionConfig, ExperimentConfig, InjectionConfig, StreamPreset, TrainMode,
};
use scadles::coordinator::{CoordinatorRuntime, RuntimeState, Trainer};
use scadles::data::LabelMap;
use scadles::harness::{self, HarnessOpts};
use scadles::runtime::Runtime;

const USAGE: &str = "\
repro — ScaDLES: scalable DL over streaming data at the edge (Rust+JAX+Pallas)

USAGE:
  repro train [--model M] [--artifacts DIR] [--devices N] [--rounds R]
              [--preset S1|S2|S1p|S2p] [--mode scadles|ddl] [--truncate]
              [--noniid K] [--cr CR --delta D] [--alpha A --beta B]
              [--jitter J] [--seed S] [--echo N] [--csv FILE]
              [--workers T]   (round-engine pool width; 0=auto, 1=sequential)
              [--hetero P]    (systems-heterogeneity scenario, name[:param]:
                               k80-homogeneous | uniform[:spread] |
                               two-tier[:frac] | lognormal-compute[:sigma] |
                               constrained-uplink[:frac])
              [--dynamics D]  (stream-dynamics scenario, stages joined with '+':
                               static | diurnal[:amp[:period]] |
                               burst[:boost[:calm[:mean_on[:mean_off]]]] |
                               churn[:frac[:period[:down]]] |
                               linkfade[:floor[:period]] | trace:PATH;
                               e.g. --dynamics diurnal:0.5 or burst:4+churn:0.25,
                               composes with --hetero)
              [--sync P]      (synchronization policy, name[:param]:
                               bsp | ksync[:frac] | stale[:s] | local[:h];
                               e.g. --sync ksync:0.75 commits each round on the
                               fastest 75% of devices; composes with --hetero
                               and --dynamics)
              [--faults F]    (mid-round fault injection, name[:params]:
                               none | crash[:frac[:train|sync]] |
                               corrupt[:frac[:scale]] | stale[:frac[:lag]] |
                               byzantine[:frac]; e.g. --faults byzantine:0.25
                               flips+amplifies 25% of device-rounds; composes
                               with --hetero/--dynamics/--sync)
              [--agg A]       (gradient combine rule: mean | trimmed[:beta] |
                               median | krum[:f]; robust rules defend against
                               --faults garbage, mean is the seed path)
              [--wire W]      (wire format for compressed exchanges:
                               f32 | q8 | q4; q8/q4 stochastically quantize
                               Top-k survivor values (per-row scale) and
                               delta-varint the indices — sync is priced from
                               the exact encoded bits; f32 is the full-
                               precision seed wire, bit for bit)
              [--net P]       (deterministic transport faults for the resilient
                               coordinator runtime, name[:params]:
                               none | lossy[:drop[:delay[:max]]] |
                               dup[:frac] | partition[:frac]; any non-none
                               preset routes the run through the rendezvous/
                               heartbeat/witness-quorum state machine — the
                               trained model stays bitwise identical to the
                               lossless run)
              [--sample K]    (per-round participant sampling: full | count k |
                               fraction in (0,1]; e.g. --sample 256 or
                               --sample 0.1 trains each round on a subset drawn
                               pure in (seed, round); full builds no sampler —
                               bitwise the unsampled engine — and --sample 1.0
                               engages the sampler over the whole fleet,
                               still bitwise identical)
              [--tiers T]     (hierarchical aggregation: flat | gateways:G;
                               devices fold into contiguous per-gateway
                               partials, gateways reduce into the cloud root,
                               each tier priced by its own link — the
                               aggregate itself stays bitwise identical to
                               flat; requires --agg mean)
              [--witnesses W] (witness-set size per round commit; 0 = every
                               committed device witnesses)
              [--quorum Q]    (witness acks required to commit; 0 = all
                               sampled witnesses; a failed quorum replays the
                               round from its pre-round snapshot)
              [--checkpoint FILE] [--checkpoint-every N] [--resume]
                              (serialize full training state to FILE — every N
                               rounds and at the end; --resume restores FILE
                               first when it exists, and the resumed run is
                               bitwise identical to an uninterrupted one)
              [--trace FILE[,fmt]]
                              (write a deterministic phase-level trace of the
                               run: fmt chrome (default; open in Perfetto or
                               chrome://tracing) or jsonl; timestamps are the
                               engine's virtual clock, so the event stream is
                               bitwise identical at any --workers width)
              [--metrics FILE]
                              (write a Prometheus text-format snapshot of the
                               run's counters/gauges at exit)
  repro exp <id|all> [--artifacts DIR] [--devices N] [--rounds R]
              [--model M] [--out-dir DIR] [--echo N] [--seed S]
              [--trace FILE[,fmt]] [--metrics FILE]
                              (per-run observability for every training run in
                               the sweep; a sanitized run label is inserted
                               before the extension so runs don't clobber)
  repro bench-check [--current rust/BENCH_hotpaths.json]
              [--baseline BENCH_baseline.json] [--tolerance 0.25]
              (CI perf gate: fail when any tracked bench case regresses
               more than tolerance vs the committed baseline; exits 0
               with a notice when no baseline exists yet)
  repro serve [--port P] [--devices N] [--rounds R] [--net P] [--quorum Q]
              [--seed S]
              (bind a TCP coordinator hub on 127.0.0.1, wait for N workers
               to rendezvous, then drive R rounds of the heartbeat/witness
               protocol over the wire — optionally through the --net fault
               wrapper — while training the simulated cluster locally)
  repro join  --device D [--port P]
              (one worker process: rendezvous with the hub, heartbeat every
               round, attest witness requests, exit on FIN)
  repro info  [--artifacts DIR]
  repro list
";

/// Minimal flag parser: `--key value` pairs plus boolean `--key` switches.
struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    fn parse(argv: &[String], switches: &[&str]) -> anyhow::Result<Self> {
        let mut values = HashMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if switches.contains(&name) {
                    flags.push(name.to_string());
                } else {
                    let val = argv
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{name} expects a value"))?;
                    values.insert(name.to_string(), val.clone());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Self {
            values,
            flags,
            positional,
        })
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.values.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("invalid value for --{key}: {e}")),
        }
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.values
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

fn parse_preset(s: &str) -> anyhow::Result<StreamPreset> {
    Ok(match s.to_lowercase().as_str() {
        "s1" => StreamPreset::S1,
        "s2" => StreamPreset::S2,
        "s1p" | "s1'" | "s1prime" => StreamPreset::S1Prime,
        "s2p" | "s2'" | "s2prime" => StreamPreset::S2Prime,
        other => bail!("unknown preset {other:?} (S1|S2|S1p|S2p)"),
    })
}

fn parse_mode(s: &str) -> anyhow::Result<TrainMode> {
    Ok(match s.to_lowercase().as_str() {
        "scadles" => TrainMode::Scadles,
        "ddl" => TrainMode::Ddl,
        other => bail!("unknown mode {other:?} (scadles|ddl)"),
    })
}

/// Split a `--trace FILE[,fmt]` spec into its path and format parts.
fn parse_trace(spec: &str) -> anyhow::Result<(String, scadles::config::TraceFormat)> {
    match spec.rsplit_once(',') {
        Some((path, fmt)) => Ok((path.to_string(), scadles::config::TraceFormat::parse(fmt)?)),
        None => Ok((spec.to_string(), scadles::config::TraceFormat::default())),
    }
}

/// The CI perf gate: compare a fresh `BENCH_hotpaths.json` against the
/// committed `BENCH_baseline.json` and fail when any case tracked by the
/// baseline regressed by more than `tolerance` (relative, on `min_ns` —
/// the noise-robust statistic). A missing baseline is a notice, not a
/// failure, so the gate bootstraps itself on the first CI run; a tracked
/// case missing from the current results *is* a failure (a silently
/// deleted benchmark would otherwise un-track a hot path).
fn bench_check(current: &str, baseline: &str, tolerance: f64) -> anyhow::Result<()> {
    use scadles::util::json::Json;

    anyhow::ensure!(
        tolerance > 0.0,
        "--tolerance must be positive (got {tolerance})"
    );
    if !std::path::Path::new(baseline).exists() {
        println!(
            "bench-check: no baseline at {baseline}; nothing to compare \
             (seed it by committing a copy of {current})"
        );
        return Ok(());
    }
    let parse = |path: &str| -> anyhow::Result<HashMap<String, f64>> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading bench results from {path}"))?;
        let doc = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        let schema = doc.get("schema")?.as_str()?;
        anyhow::ensure!(
            schema == scadles::obs::SNAPSHOT_SCHEMA,
            "{path}: unknown bench schema {schema:?}"
        );
        let mut cases = HashMap::new();
        for case in doc.get("cases")?.as_arr()? {
            cases.insert(
                case.get("name")?.as_str()?.to_string(),
                case.get("min_ns")?.as_f64()?,
            );
        }
        Ok(cases)
    };
    let base = parse(baseline)?;
    let cur = parse(current)?;

    let mut names: Vec<&String> = base.keys().collect();
    names.sort();
    let mut failures = Vec::new();
    println!(
        "bench-check: {} tracked case(s), tolerance {:.0}%",
        names.len(),
        tolerance * 100.0
    );
    for name in names {
        let b = base[name];
        let Some(&c) = cur.get(name) else {
            println!("  MISSING  {name}  (tracked in baseline, absent from {current})");
            failures.push(format!("{name}: missing from current results"));
            continue;
        };
        let ratio = if b > 0.0 { c / b } else { f64::INFINITY };
        let verdict = if ratio > 1.0 + tolerance {
            failures.push(format!(
                "{name}: {b:.0} ns -> {c:.0} ns ({:+.1}%)",
                (ratio - 1.0) * 100.0
            ));
            "REGRESS"
        } else {
            "ok"
        };
        println!(
            "  {verdict:>7}  {name}  baseline {b:.0} ns, current {c:.0} ns ({:+.1}%)",
            (ratio - 1.0) * 100.0
        );
    }
    for name in cur.keys().filter(|n| !base.contains_key(*n)) {
        println!("  new      {name}  (not yet in baseline)");
    }
    if failures.is_empty() {
        println!("bench-check: PASS");
        Ok(())
    } else {
        bail!(
            "bench-check: {} regression(s) beyond {:.0}%:\n  {}",
            failures.len(),
            tolerance * 100.0,
            failures.join("\n  ")
        )
    }
}

/// `repro serve`: bind the TCP coordinator hub, rendezvous with the
/// workers, then drive the heartbeat/witness protocol over the wire for
/// every round while the simulated cluster trains locally. The `--net`
/// fault wrapper composes over TCP exactly as it does in-proc, so the
/// localhost demo exercises the same retry machinery CI gates in
/// simulation.
fn serve(args: &Args) -> anyhow::Result<()> {
    use scadles::config::NetPreset;
    use scadles::coordinator::MockBackend;
    use scadles::transport::{FaultyTransport, TcpTransport};
    use std::time::Duration;

    let port = args.get("port", 7070u16)?;
    let devices = args.get("devices", 3usize)?;
    let rounds = args.get("rounds", 5usize)?;
    let seed = args.get("seed", 42u64)?;
    let quorum = args.get("quorum", 0usize)?;
    let net: NetPreset = args.get_str("net", "none").parse()?;

    let mut hub = TcpTransport::bind(port, devices)?;
    println!(
        "serve: listening on 127.0.0.1:{} for {devices} worker(s)",
        hub.port()?
    );
    let joined = hub.accept_joins(Duration::from_secs(60))?;
    println!("serve: rendezvous complete, devices {joined:?}");

    let cfg = ExperimentConfig::builder("mlp_c10")
        .devices(devices)
        .rounds(rounds)
        .preset(StreamPreset::S1)
        .mode(TrainMode::Scadles)
        .seed(seed)
        .build()?;
    // the TCP demo exercises the control plane; the training arithmetic
    // is the simulated cluster's (no artifacts needed)
    let mut trainer = Trainer::with_backend(&cfg, Box::new(MockBackend::new(64, 10)))?;

    if net.is_none() {
        serve_rounds(&mut trainer, hub, |_, _| {}, devices, rounds, quorum)
    } else {
        let wrapped = FaultyTransport::from_preset(hub, &net, devices, seed)
            .expect("non-none preset always wraps");
        serve_rounds(
            &mut trainer,
            wrapped,
            |t: &mut FaultyTransport<TcpTransport>, r| t.begin_round(r),
            devices,
            rounds,
            quorum,
        )
    }
}

/// The coordinator side of one `repro serve` run, generic over the
/// transport (bare TCP or the `--net` fault wrapper).
fn serve_rounds<T: scadles::transport::Transport>(
    trainer: &mut Trainer,
    mut net: T,
    mut begin_round: impl FnMut(&mut T, usize),
    devices: usize,
    rounds: usize,
    quorum: usize,
) -> anyhow::Result<()> {
    use scadles::transport::{params_digest, Envelope, Msg, COORDINATOR};
    use std::time::Duration;

    const TICK: Duration = Duration::from_millis(5);
    const WINDOW: usize = 600; // ~3 s of ticks per phase
    let needed = if quorum == 0 { devices } else { quorum.min(devices) };
    let mut misses = 0u64;
    let mut inbox = Vec::new();
    for r in 0..rounds {
        begin_round(&mut net, r);
        // liveness window: resend ROUND until every worker heartbeats
        let mut heard = vec![false; devices];
        for tick in 0..WINDOW {
            if tick % 10 == 0 {
                for d in 0..devices {
                    if !heard[d] {
                        net.send(
                            Envelope::new(
                                COORDINATOR,
                                d as u32,
                                Msg::RoundStart { round: r as u32 },
                            ),
                            0,
                        )?;
                    }
                }
            }
            std::thread::sleep(TICK);
            inbox.clear();
            net.poll(&mut inbox)?;
            for env in &inbox {
                if env.to == COORDINATOR {
                    if let Msg::Heartbeat { round } = env.msg {
                        if round == r as u32 {
                            if let Some(h) = heard.get_mut(env.from as usize) {
                                *h = true;
                            }
                        }
                    }
                }
            }
            if heard.iter().all(|&h| h) {
                break;
            }
        }
        misses += heard.iter().filter(|&&h| !h).count() as u64;

        let log = trainer.round()?;
        let digest = params_digest(trainer.params());

        // witness quorum over the wire
        let mut acked = vec![false; devices];
        let mut acks = 0usize;
        for tick in 0..WINDOW {
            if tick % 10 == 0 {
                for d in 0..devices {
                    if !acked[d] {
                        net.send(
                            Envelope::new(
                                COORDINATOR,
                                d as u32,
                                Msg::WitnessReq { round: r as u32, digest },
                            ),
                            0,
                        )?;
                    }
                }
            }
            std::thread::sleep(TICK);
            inbox.clear();
            net.poll(&mut inbox)?;
            for env in &inbox {
                if env.to == COORDINATOR {
                    if let Msg::WitnessAck { round, digest: dg } = env.msg {
                        if round == r as u32 && dg == digest {
                            if let Some(a) = acked.get_mut(env.from as usize) {
                                if !*a {
                                    *a = true;
                                    acks += 1;
                                }
                            }
                        }
                    }
                }
            }
            if acks >= needed {
                break;
            }
        }
        anyhow::ensure!(
            acks >= needed,
            "round {r}: witness quorum failed ({acks}/{needed} acks)"
        );
        for d in 0..devices {
            net.send(
                Envelope::new(COORDINATOR, d as u32, Msg::Commit { round: r as u32 }),
                0,
            )?;
        }
        println!(
            "serve: round {r} committed (loss {:.4}, {acks}/{needed} witness acks)",
            log.train_loss
        );
    }
    // FIN a few times so a lossy wrapper can't eat the goodbye
    for _ in 0..8 {
        for d in 0..devices {
            net.send(Envelope::new(COORDINATOR, d as u32, Msg::Finish), 0)?;
        }
        std::thread::sleep(TICK);
        inbox.clear();
        net.poll(&mut inbox)?;
    }
    let out = trainer.finish();
    anyhow::ensure!(
        out.report.final_train_loss.is_finite(),
        "non-finite final loss"
    );
    println!(
        "serve: {rounds} rounds committed, final_train_loss={:.6}, heartbeat_misses={misses}",
        out.report.final_train_loss
    );
    Ok(())
}

/// `repro join`: one worker process — rendezvous, then react to the
/// coordinator (heartbeat + frame on ROUND, attest on WREQ) until FIN.
fn join(args: &Args) -> anyhow::Result<()> {
    use scadles::transport::{Envelope, Msg, TcpClient, Transport, COORDINATOR};
    use std::time::{Duration, Instant};

    let port = args.get("port", 7070u16)?;
    let device: u32 = args
        .values
        .get("device")
        .context("repro join requires --device D")?
        .parse()
        .map_err(|e| anyhow!("invalid --device: {e}"))?;
    let mut c = TcpClient::connect(port, device, Duration::from_secs(60))?;
    println!("worker {device}: joined coordinator on 127.0.0.1:{port}");
    let deadline = Instant::now() + Duration::from_secs(300);
    let mut rounds_seen = 0u32;
    let mut inbox = Vec::new();
    loop {
        anyhow::ensure!(
            Instant::now() < deadline,
            "worker {device}: coordinator went quiet"
        );
        std::thread::sleep(Duration::from_millis(2));
        inbox.clear();
        c.poll(&mut inbox)?;
        for env in &inbox {
            match env.msg {
                Msg::RoundStart { round } => {
                    c.send(
                        Envelope::new(device, COORDINATOR, Msg::Heartbeat { round }),
                        0,
                    )?;
                    c.send(Envelope::new(device, COORDINATOR, Msg::Frame { round }), 0)?;
                    rounds_seen = rounds_seen.max(round + 1);
                }
                Msg::WitnessReq { round, digest } => {
                    c.send(
                        Envelope::new(device, COORDINATOR, Msg::WitnessAck { round, digest }),
                        0,
                    )?;
                }
                Msg::Finish => {
                    println!("worker {device}: finished after {rounds_seen} round(s)");
                    return Ok(());
                }
                _ => {}
            }
        }
    }
}

fn main() -> anyhow::Result<()> {
    // silence xla_extension's TfrtCpuClient chatter unless asked for
    if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
        std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "list" => {
            for e in harness::EXPERIMENTS {
                println!("{e}");
            }
            for e in harness::EXTENSIONS {
                println!("{e}  (extension)");
            }
            Ok(())
        }
        "info" => {
            let args = Args::parse(&argv[1..], &[])?;
            let rt = Runtime::load(args.get_str("artifacts", "artifacts"))?;
            let m = rt.manifest();
            println!("platform:   {}", rt.platform());
            println!("artifacts:  {}", m.dir().display());
            println!("jax:        {}", m.jax_version);
            println!("buckets:    {:?}", m.buckets);
            println!("wagg sizes: {:?}", m.device_counts);
            for (name, meta) in &m.models {
                println!(
                    "model {name}: d={} classes={} momentum={} wd={}",
                    meta.param_count, meta.num_classes, meta.momentum, meta.weight_decay
                );
            }
            println!("files:      {}", m.files.len());
            Ok(())
        }
        "exp" => {
            let args = Args::parse(&argv[1..], &[])?;
            let id = args
                .positional
                .first()
                .context("usage: repro exp <id> (see `repro list`)")?
                .clone();
            let (trace, trace_format) = match args.values.get("trace") {
                None => (None, scadles::config::TraceFormat::default()),
                Some(spec) => {
                    let (path, fmt) = parse_trace(spec)?;
                    (Some(PathBuf::from(path)), fmt)
                }
            };
            let opts = HarnessOpts {
                artifacts_dir: PathBuf::from(args.get_str("artifacts", "artifacts")),
                devices: args.get("devices", 0usize)?,
                rounds: args.get("rounds", 0usize)?,
                model: args.get_str("model", ""),
                out_dir: args.values.get("out-dir").map(PathBuf::from),
                echo_every: args.get("echo", 0usize)?,
                seed: args.get("seed", 42u64)?,
                trace,
                trace_format,
                metrics: args.values.get("metrics").map(PathBuf::from),
            };
            harness::run(&id, &opts)
        }
        "train" => {
            let args = Args::parse(&argv[1..], &["truncate", "resume"])?;
            let model = args.get_str("model", "resnet_tiny_c10");
            let mut b = ExperimentConfig::builder(&model)
                .artifacts_dir(args.get_str("artifacts", "artifacts"))
                .devices(args.get("devices", 8usize)?)
                .rounds(args.get("rounds", 50usize)?)
                .preset(parse_preset(&args.get_str("preset", "S1"))?)
                .mode(parse_mode(&args.get_str("mode", "scadles"))?)
                .rate_jitter(args.get("jitter", 0.0f64)?)
                .hetero(args.get_str("hetero", "k80-homogeneous").parse()?)
                .dynamics(args.get_str("dynamics", "static").parse()?)
                .sync(args.get_str("sync", "bsp").parse()?)
                .faults(args.get_str("faults", "none").parse()?)
                .agg(args.get_str("agg", "mean").parse()?)
                .wire(args.get_str("wire", "f32").parse()?)
                .net(args.get_str("net", "none").parse()?)
                .sample(args.get_str("sample", "full").parse()?)
                .tiers(args.get_str("tiers", "flat").parse()?)
                .witnesses(args.get("witnesses", 0usize)?)
                .quorum(args.get("quorum", 0usize)?)
                .seed(args.get("seed", 42u64)?)
                .echo_every(args.get("echo", 10usize)?)
                .worker_threads(args.get("workers", 0usize)?);
            if args.has("truncate") {
                b = b.buffer_policy(BufferPolicy::Truncation);
            }
            let noniid = args.get("noniid", 0usize)?;
            if noniid > 0 {
                b = b.label_map(LabelMap::NonIid { labels_per_device: noniid });
            }
            let cr = args.get("cr", 0.0f64)?;
            if cr > 0.0 {
                b = b.compression(CompressionConfig::new(cr, args.get("delta", 0.3f64)?));
            }
            let alpha = args.get("alpha", 0.0f64)?;
            let beta = args.get("beta", 0.0f64)?;
            if alpha > 0.0 && beta > 0.0 {
                b = b.injection(InjectionConfig::new(alpha, beta));
            }
            if let Some(spec) = args.values.get("trace") {
                let (path, fmt) = parse_trace(spec)?;
                b = b.trace_path(path).trace_format(fmt);
            }
            if let Some(path) = args.values.get("metrics") {
                b = b.metrics_path(path.as_str());
            }
            let cfg = b.build()?;
            let ckpt = args.values.get("checkpoint").map(PathBuf::from);
            let ckpt_every = args.get("checkpoint-every", 0usize)?;
            let out = if cfg.net.is_none() {
                // lossless wire: the engine runs bare (bitwise the seed path)
                let mut t = Trainer::from_config(&cfg)?;
                if args.has("resume") {
                    let path = ckpt
                        .as_deref()
                        .context("--resume requires --checkpoint FILE")?;
                    if path.exists() {
                        t.restore_checkpoint(path)?;
                        eprintln!(
                            "resumed from {} at round {}",
                            path.display(),
                            t.rounds_completed()
                        );
                    } else {
                        eprintln!(
                            "checkpoint {} not found; starting from scratch",
                            path.display()
                        );
                    }
                }
                let out = if let Some(path) = ckpt.as_deref() {
                    while t.rounds_completed() < cfg.rounds {
                        let log = t.round()?;
                        if ckpt_every > 0 && (log.round + 1) % ckpt_every == 0 {
                            t.save_checkpoint(path)?;
                        }
                    }
                    t.save_checkpoint(path)?;
                    eprintln!(
                        "checkpoint written to {} at round {}",
                        path.display(),
                        t.rounds_completed()
                    );
                    t.finish()
                } else {
                    t.run()?
                };
                t.export_obs()?;
                out
            } else {
                // faulted wire: route the run through the resilient
                // coordinator runtime (rendezvous → heartbeats →
                // witness-quorum commit, replay on a failed quorum)
                let mut rt = CoordinatorRuntime::from_config(&cfg)?;
                if args.has("resume") {
                    let path = ckpt
                        .as_deref()
                        .context("--resume requires --checkpoint FILE")?;
                    if path.exists() {
                        rt.restore_checkpoint(path)?;
                        eprintln!(
                            "resumed from {} at round {}",
                            path.display(),
                            rt.engine().rounds_completed()
                        );
                    } else {
                        eprintln!(
                            "checkpoint {} not found; starting from scratch",
                            path.display()
                        );
                    }
                }
                let out = if let Some(path) = ckpt.as_deref() {
                    while rt.state() != RuntimeState::Finished {
                        let log = rt.step()?;
                        if ckpt_every > 0 && (log.round + 1) % ckpt_every == 0 {
                            rt.save_checkpoint(path)?;
                        }
                    }
                    rt.save_checkpoint(path)?;
                    eprintln!(
                        "checkpoint written to {} at round {}",
                        path.display(),
                        rt.engine().rounds_completed()
                    );
                    rt.engine().finish()
                } else {
                    rt.run()?
                };
                rt.export_obs()?;
                eprintln!(
                    "runtime: {} heartbeat miss(es), {} retransmit(s), {} replay(s), {} witness ack(s)",
                    out.resilience.heartbeat_misses,
                    out.resilience.retransmits,
                    out.resilience.round_replays,
                    out.resilience.witness_acks,
                );
                out
            };
            println!("{}", out.report.to_json().to_string_pretty());
            if let Some(path) = args.values.get("csv") {
                let mut w = scadles::metrics::CsvWriter::create(
                    path,
                    &scadles::metrics::TRAIN_CSV_HEADER,
                )?;
                for r in out.logs.rounds() {
                    w.row(&[
                        r.round.to_string(),
                        format!("{:.3}", r.wall_clock_s),
                        r.global_batch.to_string(),
                        format!("{:.5}", r.train_loss),
                        format!("{:.4}", r.test_top1),
                        format!("{:.4}", r.test_top5),
                        format!("{:.5}", r.lr),
                        r.buffered_samples.to_string(),
                        r.floats_sent.to_string(),
                        r.compressed.to_string(),
                        r.injection_bytes.to_string(),
                        r.straggler_device.to_string(),
                        r.straggler_cause.name().into(),
                        r.active_devices.to_string(),
                        format!("{:.2}", r.rate_est),
                        r.committed_devices.to_string(),
                        r.dropped_devices.to_string(),
                        r.rejected_devices.to_string(),
                        r.faulted_devices.to_string(),
                        r.heartbeat_misses.to_string(),
                        r.retransmits.to_string(),
                        r.round_replays.to_string(),
                        r.witness_acks.to_string(),
                    ])?;
                }
                w.flush()?;
                eprintln!("wrote per-round csv to {path}");
            }
            Ok(())
        }
        "serve" => {
            let args = Args::parse(&argv[1..], &[])?;
            serve(&args)
        }
        "join" => {
            let args = Args::parse(&argv[1..], &[])?;
            join(&args)
        }
        "bench-check" => {
            let args = Args::parse(&argv[1..], &[])?;
            bench_check(
                &args.get_str("current", "rust/BENCH_hotpaths.json"),
                &args.get_str("baseline", "BENCH_baseline.json"),
                args.get("tolerance", 0.25f64)?,
            )
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            print!("{USAGE}");
            bail!("unknown command {other:?}")
        }
    }
}
