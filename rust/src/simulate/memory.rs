//! Analytic GPU-memory model (paper Figs. 2b and 3a).
//!
//! Training memory =
//!   weights + gradients + optimizer state        (scales with params)
//! + activation maps + input batch                (scales with batch size)
//! + framework/cuDNN workspace                    (fixed)
//!
//! The paper measures this on V100s for ResNet152/VGG19 at 32×32 inputs;
//! [`MemoryModel::paper_resnet152`] / [`paper_vgg19`] carry those models'
//! real parameter counts and activation footprints so the regenerated
//! curves live on the paper's scale.


/// SGD variant (paper Fig. 3a): optimizer state multiplies parameter
/// memory — none for vanilla SGD, +1 buffer for momentum, +2 for Adam.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    Sgd,
    Momentum,
    Adam,
}

impl Optimizer {
    /// Number of param-sized f32 state buffers the optimizer keeps.
    pub fn state_buffers(&self) -> usize {
        match self {
            Optimizer::Sgd => 0,
            Optimizer::Momentum => 1,
            Optimizer::Adam => 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Sgd => "minibatch-sgd",
            Optimizer::Momentum => "nesterov-momentum",
            Optimizer::Adam => "adam",
        }
    }
}

/// Memory model for one network architecture.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryModel {
    /// Trainable parameters.
    pub params: u64,
    /// Stored activation floats per input sample (backward-pass graph).
    pub activation_floats_per_sample: u64,
    /// Input floats per sample.
    pub input_floats_per_sample: u64,
    /// Fixed framework + workspace bytes (CUDA context, cuDNN workspace).
    pub fixed_bytes: u64,
}

impl MemoryModel {
    /// ResNet152 on 32×32×3 inputs (60.2M params; deep but thin — large
    /// activation count per sample relative to VGG at this resolution).
    pub fn paper_resnet152() -> Self {
        Self {
            params: 60_200_000,
            activation_floats_per_sample: 5_500_000,
            input_floats_per_sample: 3072,
            fixed_bytes: 1_200_000_000,
        }
    }

    /// VGG19 on 32×32×3 inputs (143.7M params; most memory in weights +
    /// the huge classifier, fewer conv activations at 32×32).
    pub fn paper_vgg19() -> Self {
        Self {
            params: 143_700_000,
            activation_floats_per_sample: 3_000_000,
            input_floats_per_sample: 3072,
            fixed_bytes: 1_200_000_000,
        }
    }

    /// Map a model name to its paper-scale memory class (mirrors
    /// [`crate::config::VirtualCost::for_model`]).
    pub fn for_model(model: &str) -> Self {
        if model.contains("vgg") {
            Self::paper_vgg19()
        } else {
            Self::paper_resnet152()
        }
    }

    /// Total training-resident bytes for a mini-batch of `batch` under
    /// `opt` (f32 everywhere, as the paper's fp32 runs).
    pub fn bytes(&self, batch: usize, opt: Optimizer) -> u64 {
        let param_state = self.params * 4 * (2 + opt.state_buffers() as u64); // w + g + state
        let per_sample =
            (self.activation_floats_per_sample + self.input_floats_per_sample) * 4;
        self.fixed_bytes + param_state + per_sample * batch as u64
    }

    /// Convenience: GiB.
    pub fn gib(&self, batch: usize, opt: Optimizer) -> f64 {
        self.bytes(batch, opt) as f64 / (1u64 << 30) as f64
    }

    /// Largest batch that fits in `budget_bytes` (0 when even the
    /// batch-independent state — weights, gradients, optimizer buffers,
    /// framework workspace — exceeds the budget). Inverse of
    /// [`Self::bytes`]: `bytes(b, opt) <= budget` iff `b <= max_batch`.
    pub fn max_batch(&self, budget_bytes: u64, opt: Optimizer) -> usize {
        let fixed = self.fixed_bytes + self.params * 4 * (2 + opt.state_buffers() as u64);
        if budget_bytes < fixed {
            return 0;
        }
        let per_sample =
            (self.activation_floats_per_sample + self.input_floats_per_sample) * 4;
        ((budget_bytes - fixed) / per_sample.max(1)) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_with_batch() {
        let m = MemoryModel::paper_resnet152();
        let seq: Vec<f64> = [16, 32, 64, 128, 256]
            .iter()
            .map(|&b| m.gib(b, Optimizer::Momentum))
            .collect();
        assert!(seq.windows(2).all(|w| w[1] > w[0]));
        // growth is superlinear-looking on a log-x plot ("near-exponential")
        assert!(seq[4] / seq[0] > 3.0, "{seq:?}");
    }

    #[test]
    fn optimizer_ordering_matches_fig3a() {
        let m = MemoryModel::paper_vgg19();
        let sgd = m.bytes(64, Optimizer::Sgd);
        let mom = m.bytes(64, Optimizer::Momentum);
        let adam = m.bytes(64, Optimizer::Adam);
        assert!(sgd < mom && mom < adam);
        // state deltas are exactly one/two param buffers
        assert_eq!(mom - sgd, m.params * 4);
        assert_eq!(adam - sgd, m.params * 8);
    }

    #[test]
    fn max_batch_inverts_bytes() {
        let m = MemoryModel::paper_resnet152();
        for budget in [4u64 << 30, 12 << 30, 32 << 30] {
            let cap = m.max_batch(budget, Optimizer::Momentum);
            assert!(m.bytes(cap, Optimizer::Momentum) <= budget, "budget {budget}");
            assert!(m.bytes(cap + 1, Optimizer::Momentum) > budget, "budget {budget}");
        }
        // below the fixed footprint nothing fits
        assert_eq!(m.max_batch(1 << 30, Optimizer::Momentum), 0);
        // bigger budgets, bigger batches
        assert!(
            m.max_batch(32 << 30, Optimizer::Sgd) > m.max_batch(12 << 30, Optimizer::Sgd)
        );
    }

    #[test]
    fn for_model_maps_like_virtual_cost() {
        assert_eq!(MemoryModel::for_model("vgg_tiny_c100").params, 143_700_000);
        assert_eq!(MemoryModel::for_model("resnet_tiny_c10").params, 60_200_000);
        assert_eq!(MemoryModel::for_model("mlp_c10").params, 60_200_000);
    }

    #[test]
    fn v100_scale_is_plausible() {
        // fits in a 16–32 GB V100 at the paper's batch sizes
        let m = MemoryModel::paper_resnet152();
        assert!(m.gib(64, Optimizer::Momentum) < 16.0);
        assert!(m.gib(256, Optimizer::Adam) > 5.0);
    }
}
