//! Ring-allreduce communication model (paper §II-D, Fig. 4a).
//!
//! Synchronous DDL exchanges a gradient the size of the model every
//! iteration. On `n` devices a bandwidth-optimal ring moves
//! `2·(n−1)/n · bytes` through the slowest link, in `2·(n−1)` α-latency
//! steps. This α–β model also prices ScaDLES's compressed/uncompressed
//! exchanges inside the virtual clock, so wall-clock speedups (Table VI)
//! are computed identically for ScaDLES and the DDL baseline.


/// α–β network model for gradient synchronization.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Link bandwidth in bits/second (paper testbed: 5 Gbps ethernet).
    pub bandwidth_bps: f64,
    /// Per-message latency α in seconds (docker-swarm overlay ≈ 100 µs).
    pub latency_s: f64,
    /// Protocol efficiency (payload fraction of line rate).
    pub efficiency: f64,
}

impl NetworkModel {
    /// The paper's testbed: 5 Gbps ethernet, overlay-network latency.
    pub fn paper_5gbps() -> Self {
        Self {
            bandwidth_bps: 5e9,
            latency_s: 100e-6,
            efficiency: 0.9,
        }
    }

    /// Ring-allreduce time for `bytes` across `n` devices, all links at
    /// the model's global bandwidth.
    pub fn allreduce_time(&self, bytes: u64, n: usize) -> f64 {
        self.allreduce_time_slowest(bytes, n, self.bandwidth_bps)
    }

    /// Ring-allreduce for `bytes` across `n` devices when the slowest
    /// participating link runs at `slowest_bps`. A bandwidth-optimal ring
    /// moves every chunk through every link, so heterogeneous clusters
    /// are throttled by the narrowest one; α latency and protocol
    /// efficiency stay the model's.
    pub fn allreduce_time_slowest(&self, bytes: u64, n: usize, slowest_bps: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let steps = 2 * (n - 1);
        let volume = 2.0 * (n as f64 - 1.0) / n as f64 * bytes as f64;
        steps as f64 * self.latency_s + volume * 8.0 / (slowest_bps * self.efficiency)
    }

    /// Allreduce for a model of `params` f32 gradients.
    pub fn gradient_sync_time(&self, params: u64, n: usize) -> f64 {
        self.allreduce_time(params * 4, n)
    }

    /// Point-to-point transfer time for `bytes` (used by data injection:
    /// β·S samples broadcast from α·D devices, Fig. 10).
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 * 8.0 / (self.bandwidth_bps * self.efficiency)
    }

    /// Sparse exchange: Top-k sends (index, value) pairs — 8 bytes per
    /// surviving element (the paper's "floats sent" metric counts 4-byte
    /// floats; CNC accounting uses [`crate::compress::cnc`]).
    ///
    /// `nnz` is the **real** survivor count of the exchange (the round
    /// engine reports Σ nnz from the mask phase and scales it exactly
    /// onto the priced model) — not a CR-derived estimate.
    pub fn sparse_sync_time(&self, nnz: u64, n: usize) -> f64 {
        self.sparse_sync_time_slowest(nnz, n, self.bandwidth_bps)
    }

    /// [`Self::sparse_sync_time`] through a heterogeneous/faded ring's
    /// slowest participating link.
    pub fn sparse_sync_time_slowest(&self, nnz: u64, n: usize, slowest_bps: f64) -> f64 {
        self.allreduce_time_slowest(nnz * 8, n, slowest_bps)
    }

    /// Quantized sparse exchange (`--wire q8|q4`): priced from the
    /// *exact encoded bit count* the wire format reports
    /// ([`crate::compress::QuantizedGrad::encoded_bits`] — per-row
    /// scale + sign/level stream + delta-varint indices), rounded up to
    /// whole bytes, instead of the 8-bytes-per-survivor f32 wire.
    pub fn quantized_sync_time(&self, bits: u64, n: usize) -> f64 {
        self.quantized_sync_time_slowest(bits, n, self.bandwidth_bps)
    }

    /// [`Self::quantized_sync_time`] through a heterogeneous/faded
    /// ring's slowest participating link.
    pub fn quantized_sync_time_slowest(&self, bits: u64, n: usize, slowest_bps: f64) -> f64 {
        self.allreduce_time_slowest(bits.div_ceil(8), n, slowest_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_device_is_free() {
        let m = NetworkModel::paper_5gbps();
        assert_eq!(m.gradient_sync_time(60_200_000, 1), 0.0);
    }

    #[test]
    fn paper_scale_sync_times() {
        // Paper §II-D: ResNet152/VGG19 on 8 devices spend ~1-2s syncing
        // (~80-90% of a 1.2-1.6s iteration); our 5 Gbps α-β model should land
        // in the same ballpark.
        let m = NetworkModel::paper_5gbps();
        let resnet = m.gradient_sync_time(60_200_000, 8);
        let vgg = m.gradient_sync_time(143_700_000, 8);
        assert!(resnet > 0.3 && resnet < 2.0, "resnet sync {resnet}");
        assert!(vgg > resnet, "vgg must cost more: {vgg} vs {resnet}");
    }

    #[test]
    fn sync_time_increases_with_devices() {
        let m = NetworkModel::paper_5gbps();
        let t8 = m.gradient_sync_time(60_200_000, 8);
        let t16 = m.gradient_sync_time(60_200_000, 16);
        assert!(t16 > t8);
    }

    #[test]
    fn slowest_link_pricing_matches_global_when_equal() {
        let m = NetworkModel::paper_5gbps();
        for n in [2usize, 8, 32] {
            let a = m.allreduce_time(60_200_000 * 4, n);
            let b = m.allreduce_time_slowest(60_200_000 * 4, n, m.bandwidth_bps);
            assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
        }
    }

    #[test]
    fn narrow_link_throttles_allreduce() {
        let m = NetworkModel::paper_5gbps();
        let fast = m.allreduce_time_slowest(60_200_000 * 4, 8, 5e9);
        let slow = m.allreduce_time_slowest(60_200_000 * 4, 8, 1e9);
        assert!(slow > fast * 4.0, "slow {slow} vs fast {fast}");
        // a single device rings with nobody regardless of its link
        assert_eq!(m.allreduce_time_slowest(1 << 20, 1, 1e3), 0.0);
    }

    #[test]
    fn compression_reduces_time_proportionally() {
        let m = NetworkModel::paper_5gbps();
        let dense = m.gradient_sync_time(10_000_000, 16);
        // CR=0.1 with 8-byte sparse elements → 0.2× the dense volume
        let sparse = m.sparse_sync_time(1_000_000, 16);
        assert!(sparse < dense * 0.25, "sparse {sparse} dense {dense}");
    }

    #[test]
    fn quantized_pricing_tracks_encoded_bits() {
        let m = NetworkModel::paper_5gbps();
        // the same exchange priced from bits equals the byte-count path
        let a = m.sparse_sync_time(1_000_000, 8);
        let b = m.quantized_sync_time(1_000_000 * 64, 8);
        assert_eq!(a.to_bits(), b.to_bits());
        // q8 at ~17 bits/survivor (9 value + ~8 index) beats the 64-bit
        // f32 wire for the same survivor count
        let q8 = m.quantized_sync_time(1_000_000 * 17, 8);
        assert!(q8 < a * 0.3, "q8 {q8} vs f32 {a}");
        // bit counts round up to whole bytes; sub-byte tails never price
        // as zero volume
        assert!(m.quantized_sync_time(3, 2) > 2.0 * m.latency_s);
        // slowest-link variant throttles like the sparse path
        let narrow = m.quantized_sync_time_slowest(1_000_000 * 17, 8, 1e9);
        assert!(narrow > q8 * 4.0);
    }

    #[test]
    fn sparse_slowest_link_matches_global_when_equal_and_throttles_otherwise() {
        let m = NetworkModel::paper_5gbps();
        let a = m.sparse_sync_time(2_000_000, 8);
        let b = m.sparse_sync_time_slowest(2_000_000, 8, m.bandwidth_bps);
        assert_eq!(a.to_bits(), b.to_bits());
        let narrow = m.sparse_sync_time_slowest(2_000_000, 8, 1e9);
        assert!(narrow > a * 4.0, "narrow {narrow} vs {a}");
    }
}
