//! Streaming latency + queue-growth models (paper §II-A and §II-C).

/// Latency for a device with streaming rate `rate` (samples/s) to gather a
/// mini-batch of `batch` samples: `b / p` seconds (paper §II-A).
pub fn gather_latency(rate: f64, batch: usize) -> f64 {
    batch as f64 / rate.max(f64::MIN_POSITIVE)
}

/// Per-device latencies to gather `batch`, for a set of streaming rates
/// (Fig. 1 plots mean ± spread of these across sampled devices).
pub fn streaming_latency(rates: &[f64], batch: usize) -> Vec<f64> {
    rates.iter().map(|&r| gather_latency(r, batch)).collect()
}

/// The synchronous-training straggler latency: slowest device dominates.
pub fn straggler_latency(rates: &[f64], batch: usize) -> f64 {
    streaming_latency(rates, batch)
        .into_iter()
        .fold(0.0, f64::max)
}

/// Samples buffered after `t_steps` iterations — paper Eqn. 2:
/// `Q_i = (t_i · S_i − b_i) · T + S_i`, valid while `t_i · S_i ≥ b_i`
/// (otherwise the device consumes the stream at line rate and the buffer
/// stays at ≈ S_i).
pub fn queue_growth(iter_time: f64, rate: f64, batch: usize, t_steps: u64) -> f64 {
    let inflow_per_iter = iter_time * rate;
    if inflow_per_iter >= batch as f64 {
        (inflow_per_iter - batch as f64) * t_steps as f64 + rate
    } else {
        rate
    }
}

/// High-rate limit — paper Eqn. 3: `Q_i = T · t_i · S_i + S_i` when
/// `t_i · S_i ≫ b_i`.
pub fn queue_growth_high_rate(iter_time: f64, rate: f64, t_steps: u64) -> f64 {
    t_steps as f64 * iter_time * rate + rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_is_b_over_p() {
        assert_eq!(gather_latency(100.0, 200), 2.0);
        let l = streaming_latency(&[50.0, 100.0, 200.0], 100);
        assert_eq!(l, vec![2.0, 1.0, 0.5]);
        assert_eq!(straggler_latency(&[50.0, 100.0, 200.0], 100), 2.0);
    }

    #[test]
    fn queue_growth_matches_eqn2() {
        // t=1.2s, S=100/s, b=64: inflow/iter = 120 ≥ 64
        // Q(T) = (120-64)·T + 100
        assert_eq!(queue_growth(1.2, 100.0, 64, 1000), 56.0 * 1000.0 + 100.0);
    }

    #[test]
    fn low_rate_buffer_stays_at_s() {
        // inflow/iter = 12 < 64: device trains at line rate
        assert_eq!(queue_growth(1.2, 10.0, 64, 100_000), 10.0);
    }

    #[test]
    fn high_rate_limit_matches_eqn3_and_table2() {
        // Table II row: ResNet152 t=1.2, S=100, T=1e5 → 34.33 GB at 3KB
        let q = queue_growth_high_rate(1.2, 100.0, 100_000);
        let gb = q * 3072.0 / (1u64 << 30) as f64;
        assert!((gb - 34.33).abs() < 0.05, "gb={gb}");
        // Table II row: VGG19 t=1.6, S=600, T=1e5 → 274.83 GB
        let q = queue_growth_high_rate(1.6, 600.0, 100_000);
        let gb = q * 3072.0 / (1u64 << 30) as f64;
        assert!((gb - 274.66).abs() < 0.5, "gb={gb}");
    }

    #[test]
    fn eqn2_approaches_eqn3_when_batch_negligible() {
        let full = queue_growth(1.5, 600.0, 8, 10_000);
        let high = queue_growth_high_rate(1.5, 600.0, 10_000);
        assert!((full - high).abs() / high < 0.01);
    }
}
