//! Analytic simulators for the paper's §II motivation studies.
//!
//! These model the phenomena the paper *measures* on its K80 testbed, so
//! the corresponding figures can be regenerated without that hardware
//! (DESIGN.md §2):
//!
//! * [`queue`]   — streaming latency (Fig. 1) and buffer growth Eqns. 2–3
//!   (Fig. 3b, Table II).
//! * [`memory`]  — GPU memory vs batch size and optimizer (Figs. 2b, 3a).
//! * [`network`] — ring-allreduce gradient synchronization cost on a
//!   bandwidth-limited edge network (Fig. 4a); also used by the virtual
//!   clock to price communication in training runs.
//! * [`scaling`] — throughput scaling vs device count (Fig. 4b).

pub mod memory;
pub mod network;
pub mod queue;
pub mod scaling;

pub use memory::{MemoryModel, Optimizer};
pub use network::NetworkModel;
pub use queue::{queue_growth, queue_growth_high_rate, streaming_latency};
pub use scaling::{relative_throughput, ThroughputModel};
