//! Throughput-scaling model (paper §II-D, Fig. 4b).
//!
//! Ideal scaling: `n` devices → `n×` throughput. Real scaling divides the
//! extra samples by a growing synchronization term, which is why the paper
//! sees only ~5× (ResNet152) and ~4× (VGG19) on 16 K80s.


use super::network::NetworkModel;

/// Compute+communicate model for one DDL configuration.
#[derive(Debug, Clone, Copy)]
pub struct ThroughputModel {
    /// Single-device iteration compute time at the reference batch (s).
    pub compute_time: f64,
    /// Per-device mini-batch (samples/iteration).
    pub batch: usize,
    /// Gradient size in parameters.
    pub params: u64,
    pub network: NetworkModel,
}

impl ThroughputModel {
    /// Paper ResNet152 on K80 (60.2M params). `compute_time` is the
    /// single-device fwd+bwd at b=64 — the paper's 1.2 s *distributed*
    /// iteration is 80–90% synchronization (§II-D), leaving ~0.5 s compute.
    pub fn paper_resnet152() -> Self {
        Self {
            compute_time: 0.5,
            batch: 64,
            params: 60_200_000,
            network: NetworkModel::paper_5gbps(),
        }
    }

    /// Paper VGG19 on K80 (143.7M params); ~0.7 s single-device compute.
    pub fn paper_vgg19() -> Self {
        Self {
            compute_time: 0.7,
            batch: 64,
            params: 143_700_000,
            network: NetworkModel::paper_5gbps(),
        }
    }

    /// Samples/second on `n` devices (synchronous data parallel).
    pub fn throughput(&self, n: usize) -> f64 {
        let iter = self.compute_time + self.network.gradient_sync_time(self.params, n);
        n as f64 * self.batch as f64 / iter
    }
}

/// Throughput of `n` devices relative to one device (Fig. 4b's y-axis).
pub fn relative_throughput(m: &ThroughputModel, n: usize) -> f64 {
    m.throughput(n) / m.throughput(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sublinear_scaling() {
        let m = ThroughputModel::paper_resnet152();
        let r16 = relative_throughput(&m, 16);
        assert!(r16 < 16.0, "must be sublinear: {r16}");
        assert!(r16 > 1.0);
    }

    #[test]
    fn fig4b_paper_shape() {
        // Paper: ~5× for ResNet152, ~4× for VGG19 at 16 devices.
        let r = relative_throughput(&ThroughputModel::paper_resnet152(), 16);
        let v = relative_throughput(&ThroughputModel::paper_vgg19(), 16);
        assert!(r > 4.0 && r < 8.0, "resnet rel {r}");
        assert!(v > 3.0 && v < 6.0, "vgg rel {v}");
        assert!(v < r, "vgg scales worse (bigger gradients): {v} vs {r}");
    }

    #[test]
    fn monotone_in_devices_beyond_two() {
        // n=1→2 can regress for huge gradients (the whole gradient suddenly
        // crosses the wire); from n=2 on, ring-allreduce volume per device
        // saturates and adding devices adds throughput.
        let m = ThroughputModel::paper_vgg19();
        let mut last = 0.0;
        for n in [2, 4, 8, 16] {
            let t = m.throughput(n);
            assert!(t > last, "n={n}: {t} <= {last}");
            last = t;
        }
    }
}
