//! Deterministic RNG + the sampling distributions of Table I.
//!
//! The paper samples device streaming rates from uniform and normal
//! distributions (Table I: U(38, 24), U(300, 112), N(64, 24), N(256, 28),
//! given as mean/std-dev). Everything in this crate that needs randomness
//! (stream rates, synthetic data, injection choices, shuffles) goes through
//! [`Pcg64`] so every experiment is reproducible from a single seed — a
//! requirement for like-for-like ScaDLES-vs-DDL comparisons.

/// PCG-XSH-RR 64/32 with 64-bit output (two draws), split-mix seeded.
///
/// Small, fast, and statistically solid for simulation workloads; avoids
/// pulling the `rand` crate into the runtime dependency set.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg64 {
    /// Seed deterministically; `stream` decorrelates sub-generators derived
    /// from the same seed (device id, producer id, ...).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    /// Derive an independent child generator (e.g. per device).
    pub fn fork(&mut self, stream: u64) -> Self {
        Self::new(self.next_u64(), stream)
    }

    /// Raw `(state, inc)` cursor for checkpointing.
    pub fn raw_state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator at an exact `(state, inc)` cursor (checkpoint
    /// restore; bitwise-resumes the stream where [`Self::raw_state`] cut it).
    pub fn from_raw(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased enough for
    /// simulation; exact rejection for small `n`).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// A streaming-rate distribution from Table I (mean/std parameterization).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateDistribution {
    /// Uniform with given mean and std-dev: samples from
    /// `[mean - √3·std, mean + √3·std]` (matching the moments).
    Uniform { mean: f64, std: f64 },
    /// Normal with given mean and std-dev, truncated at 1 sample/s.
    Normal { mean: f64, std: f64 },
}

impl RateDistribution {
    /// Draw one streaming rate (samples/second), clamped to >= 1.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        let v = match *self {
            RateDistribution::Uniform { mean, std } => {
                let half = 3f64.sqrt() * std;
                rng.uniform(mean - half, mean + half)
            }
            RateDistribution::Normal { mean, std } => rng.normal_ms(mean, std),
        };
        v.max(1.0)
    }

    /// Draw `n` device rates.
    pub fn sample_n(&self, rng: &mut Pcg64, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    pub fn mean(&self) -> f64 {
        match *self {
            RateDistribution::Uniform { mean, .. } | RateDistribution::Normal { mean, .. } => mean,
        }
    }

    pub fn std(&self) -> f64 {
        match *self {
            RateDistribution::Uniform { std, .. } | RateDistribution::Normal { std, .. } => std,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_decorrelate() {
        let mut a = Pcg64::new(7, 1);
        let mut b = Pcg64::new(7, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_moments_match_table1() {
        // S1: uniform mean 38, std 24
        let d = RateDistribution::Uniform { mean: 38.0, std: 24.0 };
        let mut rng = Pcg64::new(1, 0);
        let xs = d.sample_n(&mut rng, 20_000);
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!((m - 38.0).abs() < 1.5, "mean {m}");
        assert!((v.sqrt() - 24.0).abs() < 1.5, "std {}", v.sqrt());
    }

    #[test]
    fn normal_moments_match_table1() {
        // S2': normal mean 256, std 28
        let d = RateDistribution::Normal { mean: 256.0, std: 28.0 };
        let mut rng = Pcg64::new(2, 0);
        let xs = d.sample_n(&mut rng, 20_000);
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((m - 256.0).abs() < 1.5, "mean {m}");
    }

    #[test]
    fn rates_clamped_positive() {
        let d = RateDistribution::Normal { mean: 2.0, std: 50.0 };
        let mut rng = Pcg64::new(3, 0);
        assert!(d.sample_n(&mut rng, 1000).iter().all(|&r| r >= 1.0));
    }

    #[test]
    fn choose_is_distinct_subset() {
        let mut rng = Pcg64::new(4, 0);
        let mut picked = rng.choose(10, 4);
        picked.sort_unstable();
        picked.dedup();
        assert_eq!(picked.len(), 4);
        assert!(picked.iter().all(|&i| i < 10));
    }

    #[test]
    fn choose_clamps_k() {
        let mut rng = Pcg64::new(5, 0);
        assert_eq!(rng.choose(3, 10).len(), 3);
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg64::new(6, 0);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
