//! Minimal JSON parser + writer.
//!
//! Used to read `artifacts/manifest.json` (written by `aot.py`) and to
//! emit run reports. Supports the full JSON grammar (objects, arrays,
//! strings with escapes, numbers, bools, null); numbers are kept as f64,
//! which is exact for every integer the manifest contains (< 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    // ---- writer ----------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => bail!("expected ',' or '}}', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(arr));
                }
                other => bail!("expected ',' or ']', found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // surrogate pairs
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1..self.pos + 3) == Some(b"\\u") {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 3..self.pos + 7)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let low =
                                        u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    bail!("lone high surrogate")
                                }
                            } else {
                                code
                            };
                            out.push(char::from_u32(ch).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        other => bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shaped_document() {
        let doc = r#"{
            "version": 1,
            "models": {"mlp_c10": {"param_count": 820874, "buckets": [8, 16]}},
            "files": {"a.hlo.txt": {"kind": "train_step", "bucket": 8}},
            "ok": true, "none": null, "pi": 3.5
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
        let m = j.get("models").unwrap().get("mlp_c10").unwrap();
        assert_eq!(m.get("param_count").unwrap().as_usize().unwrap(), 820_874);
        assert_eq!(m.get("buckets").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(*j.get("none").unwrap(), Json::Null);
        assert_eq!(j.get("pi").unwrap().as_f64().unwrap(), 3.5);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let j = Json::Str("a\"b\\c\nd\tπ".into());
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn writer_roundtrips_nested() {
        let j = Json::obj(vec![
            ("arr", Json::Arr(vec![Json::num(1.0), Json::Bool(false), Json::Null])),
            ("s", Json::str("x")),
            ("neg", Json::num(-2.25)),
        ]);
        for text in [j.to_string(), j.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), j);
        }
    }

    #[test]
    fn integers_print_without_exponent() {
        assert_eq!(Json::num(820874.0).to_string(), "820874");
    }
}
