//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`Bench`] to time closures with warm-up,
//! multiple samples, and mean/std/min reporting — enough to drive the
//! §Perf iteration loop and the paper-table regeneration benches.
//! [`Bench::write_json`] additionally emits the machine-readable
//! `BENCH_hotpaths.json` trajectory CI archives per run, so ns/op per
//! case can be compared across PRs instead of asserted from memory.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl Sample {
    /// Nanoseconds per iteration (mean).
    pub fn ns_per_iter(&self) -> f64 {
        self.mean.as_nanos() as f64
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std),
            fmt_dur(self.min),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a fixed time budget per case.
pub struct Bench {
    /// Target measuring time per case.
    pub budget: Duration,
    /// Measurement batches (samples for the std estimate).
    pub samples: usize,
    results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            budget: Duration::from_millis(800),
            samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Time `f`, preventing the result from being optimized away.
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Sample {
        // warm-up + calibration: find iters/sample that fits the budget
        let t0 = Instant::now();
        std::hint::black_box(f());
        let one = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = (self.budget.as_secs_f64() / self.samples as f64
            / one.as_secs_f64())
        .clamp(1.0, 1e7) as u64;

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_secs_f64() / per_sample as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let sample = Sample {
            name: name.to_string(),
            iters: per_sample * self.samples as u64,
            mean: Duration::from_secs_f64(mean),
            std: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(min),
        };
        println!("{}", sample.report());
        self.results.push(sample);
        self.results.last().unwrap()
    }

    pub fn header(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "case", "mean", "std", "min"
        );
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }

    /// Serialize every measured case as machine-readable JSON in the
    /// shared [`crate::obs::export::SNAPSHOT_SCHEMA`] envelope:
    ///
    /// ```json
    /// { "schema": "scadles-bench-v1",
    ///   "cases": [ { "name": "agg/sparse-native", "ns_per_iter": …,
    ///                "min_ns": …, "std_ns": …, "iters": … }, … ] }
    /// ```
    ///
    /// CI writes this to `BENCH_hotpaths.json` and uploads it as an
    /// artifact — the perf trajectory future PRs diff against. The
    /// envelope is the same one the metrics exporter's counter
    /// snapshot uses, so `repro bench-check` can parse either.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let cases: Vec<Json> = self
            .results
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("name", Json::str(s.name.clone())),
                    ("ns_per_iter", Json::num(s.ns_per_iter())),
                    ("min_ns", Json::num(s.min.as_nanos() as f64)),
                    ("std_ns", Json::num(s.std.as_nanos() as f64)),
                    ("iters", Json::num(s.iters as f64)),
                ])
            })
            .collect();
        crate::obs::export::snapshot_json(cases)
    }

    /// Write [`Self::to_json`] to `path` (pretty-printed, trailing
    /// newline so the artifact diffs cleanly).
    pub fn write_json(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
            .map_err(|e| anyhow::anyhow!("writing bench json to {}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::new().with_budget(Duration::from_millis(50));
        let s = b.case("noop-ish", || std::hint::black_box(42u64).wrapping_mul(3));
        assert!(s.mean < Duration::from_micros(50));
        assert!(s.iters > 0);
    }

    #[test]
    fn json_emission_round_trips() {
        use crate::util::json::Json;
        let mut b = Bench::new().with_budget(Duration::from_millis(20));
        b.case("fast/one", || (0..500u64).map(std::hint::black_box).sum::<u64>());
        b.case("fast/two", || (0..1000u64).map(std::hint::black_box).sum::<u64>());
        let parsed = Json::parse(&b.to_json().to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("schema").unwrap().as_str().unwrap(),
            crate::obs::export::SNAPSHOT_SCHEMA
        );
        let cases = parsed.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 2);
        assert_eq!(cases[0].get("name").unwrap().as_str().unwrap(), "fast/one");
        assert!(cases[0].get("ns_per_iter").unwrap().as_f64().unwrap() > 0.0);
        assert!(cases[1].get("iters").unwrap().as_u64().unwrap() > 0);
        // file round trip
        let path = std::env::temp_dir().join(format!(
            "scadles_bench_json_{}.json",
            std::process::id()
        ));
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(Json::parse(text.trim_end()).unwrap(), parsed);
    }

    #[test]
    fn scales_with_work() {
        let mut b = Bench::new().with_budget(Duration::from_millis(60));
        let small = b
            .case("sum-1k", || (0..1_000u64).sum::<u64>())
            .ns_per_iter();
        let large = b
            .case("sum-100k", || (0..100_000u64).sum::<u64>())
            .ns_per_iter();
        assert!(large > small * 10.0, "{large} vs {small}");
    }
}
