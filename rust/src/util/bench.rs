//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use [`Bench`] to time closures with warm-up,
//! multiple samples, and mean/std/min reporting — enough to drive the
//! §Perf iteration loop and the paper-table regeneration benches.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl Sample {
    /// Nanoseconds per iteration (mean).
    pub fn ns_per_iter(&self) -> f64 {
        self.mean.as_nanos() as f64
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_dur(self.mean),
            fmt_dur(self.std),
            fmt_dur(self.min),
            self.iters
        )
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a fixed time budget per case.
pub struct Bench {
    /// Target measuring time per case.
    pub budget: Duration,
    /// Measurement batches (samples for the std estimate).
    pub samples: usize,
    results: Vec<Sample>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            budget: Duration::from_millis(800),
            samples: 10,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_budget(mut self, budget: Duration) -> Self {
        self.budget = budget;
        self
    }

    /// Time `f`, preventing the result from being optimized away.
    pub fn case<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Sample {
        // warm-up + calibration: find iters/sample that fits the budget
        let t0 = Instant::now();
        std::hint::black_box(f());
        let one = t0.elapsed().max(Duration::from_nanos(50));
        let per_sample = (self.budget.as_secs_f64() / self.samples as f64
            / one.as_secs_f64())
        .clamp(1.0, 1e7) as u64;

        let mut times = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..per_sample {
                std::hint::black_box(f());
            }
            times.push(t.elapsed().as_secs_f64() / per_sample as f64);
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let sample = Sample {
            name: name.to_string(),
            iters: per_sample * self.samples as u64,
            mean: Duration::from_secs_f64(mean),
            std: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(min),
        };
        println!("{}", sample.report());
        self.results.push(sample);
        self.results.last().unwrap()
    }

    pub fn header(&self, title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "case", "mean", "std", "min"
        );
    }

    pub fn results(&self) -> &[Sample] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::new().with_budget(Duration::from_millis(50));
        let s = b.case("noop-ish", || std::hint::black_box(42u64).wrapping_mul(3));
        assert!(s.mean < Duration::from_micros(50));
        assert!(s.iters > 0);
    }

    #[test]
    fn scales_with_work() {
        let mut b = Bench::new().with_budget(Duration::from_millis(60));
        let small = b
            .case("sum-1k", || (0..1_000u64).sum::<u64>())
            .ns_per_iter();
        let large = b
            .case("sum-100k", || (0..100_000u64).sum::<u64>())
            .ns_per_iter();
        assert!(large > small * 10.0, "{large} vs {small}");
    }
}
