//! Small self-contained utilities (the sandbox builds fully offline, so
//! substrates that would normally be crates are implemented here).

pub mod bench;
pub mod json;

pub use json::Json;
