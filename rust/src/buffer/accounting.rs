//! Per-round buffer accounting across devices.


use crate::stream::record::SAMPLE_PAYLOAD_BYTES;

/// Tracks cluster-wide queue sizes over training rounds.
#[derive(Debug, Clone, Default)]
pub struct BufferTracker {
    /// Per-round total buffered samples (sum over devices).
    history: Vec<u64>,
    /// Peak total buffered samples.
    peak: u64,
}

/// Summary of a tracked run (basis for Fig. 8 / Tables IV & VI and the
/// dynamics sweep's occupancy percentiles).
#[derive(Debug, Clone, Copy, Default)]
pub struct BufferReport {
    /// Buffered samples at the final round.
    pub final_samples: u64,
    /// Peak buffered samples over the run.
    pub peak_samples: u64,
    /// Median / 90th-percentile buffered samples over the run
    /// (nearest-rank; time-varying streams make the occupancy
    /// *distribution* the interesting quantity, not just the endpoints).
    pub p50_samples: u64,
    pub p90_samples: u64,
    /// Final buffered payload in gigabytes (3 KB/sample, as the paper).
    pub final_gb: f64,
    pub peak_gb: f64,
    pub rounds: usize,
}

impl BufferTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the total buffered sample count at the end of a round.
    pub fn record(&mut self, total_buffered: u64) {
        self.peak = self.peak.max(total_buffered);
        self.history.push(total_buffered);
    }

    pub fn history(&self) -> &[u64] {
        &self.history
    }

    pub fn last(&self) -> u64 {
        self.history.last().copied().unwrap_or(0)
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Nearest-rank percentile of the per-round occupancy history
    /// (`q` in [0,1]; 0 on an empty history).
    pub fn percentile(&self, q: f64) -> u64 {
        if self.history.is_empty() {
            return 0;
        }
        let mut sorted = self.history.clone();
        sorted.sort_unstable();
        let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize)
            .clamp(1, sorted.len());
        sorted[rank - 1]
    }

    pub fn report(&self) -> BufferReport {
        BufferReport {
            final_samples: self.last(),
            peak_samples: self.peak,
            p50_samples: self.percentile(0.5),
            p90_samples: self.percentile(0.9),
            final_gb: samples_to_gb(self.last()),
            peak_gb: samples_to_gb(self.peak),
            rounds: self.history.len(),
        }
    }
}

/// Convert buffered samples to "GB" at the paper's 3 KB/image.
///
/// The paper's Table II numbers are binary gigabytes (2³⁰ bytes):
/// T=1e5 · t=1.2s · S=100 · 3072 B = 34.33 — exactly their entry.
pub fn samples_to_gb(samples: u64) -> f64 {
    samples as f64 * SAMPLE_PAYLOAD_BYTES as f64 / (1u64 << 30) as f64
}

/// Reduction factor between two buffer footprints (Table IV's
/// "Persistence / Truncation" column); ∞-safe.
pub fn reduction_factor(persistence: u64, truncation: u64) -> f64 {
    persistence as f64 / truncation.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak_and_final() {
        let mut t = BufferTracker::new();
        for v in [10, 50, 30] {
            t.record(v);
        }
        let r = t.report();
        assert_eq!(r.final_samples, 30);
        assert_eq!(r.peak_samples, 50);
        assert_eq!(r.rounds, 3);
        assert_eq!(r.p50_samples, 30);
        assert_eq!(r.p90_samples, 50);
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let mut t = BufferTracker::new();
        for v in 1..=100u64 {
            t.record(v);
        }
        assert_eq!(t.percentile(0.5), 50);
        assert_eq!(t.percentile(0.9), 90);
        assert_eq!(t.percentile(0.0), 1); // floored at the first rank
        assert_eq!(t.percentile(1.0), 100);
        assert_eq!(BufferTracker::new().percentile(0.5), 0);
    }

    #[test]
    fn gb_conversion_matches_paper_scale() {
        // Table II: ResNet152 t=1.2s S=100 T=1e5 → 34.33 GB
        // samples ≈ T·t·S = 1.2e7 → ·3072B = 36.8 GB (same scale; the paper
        // rounds with 1024-based GB: 1.2e7·3072/2^30 = 34.33 GiB exactly).
        let samples = 100_000.0 * 1.2 * 100.0;
        let gib = samples * 3072.0 / (1u64 << 30) as f64;
        assert!((gib - 34.33).abs() < 0.05, "gib {gib}");
    }

    #[test]
    fn reduction_factor_safe() {
        assert_eq!(reduction_factor(1000, 10), 100.0);
        assert_eq!(reduction_factor(1000, 0), 1000.0);
    }
}
