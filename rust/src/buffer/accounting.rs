//! Per-round buffer accounting across devices.


use crate::stream::record::SAMPLE_PAYLOAD_BYTES;

/// Tracks cluster-wide queue sizes over training rounds.
#[derive(Debug, Clone, Default)]
pub struct BufferTracker {
    /// Per-round total buffered samples (sum over devices).
    history: Vec<u64>,
    /// Peak total buffered samples.
    peak: u64,
    /// Reused selection buffer for [`Self::percentile`]: `report()`
    /// asks for two percentiles per call, and a clone-and-full-sort per
    /// ask is O(r log r) with a fresh allocation each time; select-nth
    /// over one warm scratch is O(r) and allocation-free once the
    /// capacity covers the history. `RefCell` keeps the accessor `&self`
    /// (reports are taken from shared borrows of the trainer).
    scratch: std::cell::RefCell<Vec<u64>>,
}

/// Summary of a tracked run (basis for Fig. 8 / Tables IV & VI and the
/// dynamics sweep's occupancy percentiles).
#[derive(Debug, Clone, Copy, Default)]
pub struct BufferReport {
    /// Buffered samples at the final round.
    pub final_samples: u64,
    /// Peak buffered samples over the run.
    pub peak_samples: u64,
    /// Median / 90th-percentile buffered samples over the run
    /// (nearest-rank; time-varying streams make the occupancy
    /// *distribution* the interesting quantity, not just the endpoints).
    pub p50_samples: u64,
    pub p90_samples: u64,
    /// Final buffered payload in gigabytes (3 KB/sample, as the paper).
    pub final_gb: f64,
    pub peak_gb: f64,
    pub rounds: usize,
}

impl BufferTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the total buffered sample count at the end of a round.
    pub fn record(&mut self, total_buffered: u64) {
        self.peak = self.peak.max(total_buffered);
        self.history.push(total_buffered);
    }

    pub fn history(&self) -> &[u64] {
        &self.history
    }

    pub fn last(&self) -> u64 {
        self.history.last().copied().unwrap_or(0)
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    /// Restore the tracker from a saved history (checkpointing); `peak`
    /// is re-derived — `record` never lets it exceed the history max.
    pub fn restore(&mut self, history: &[u64]) {
        self.history.clear();
        self.history.extend_from_slice(history);
        self.peak = history.iter().copied().max().unwrap_or(0);
    }

    /// Nearest-rank percentile of the per-round occupancy history
    /// (`q` in [0,1]; 0 on an empty history).
    ///
    /// Nearest-rank needs only the element at sorted position
    /// `rank − 1`, so this runs `select_nth_unstable` (O(r) average,
    /// in-place) over a reused scratch copy instead of cloning and
    /// fully sorting the history on every call. Results are pinned
    /// against the sort-based definition by
    /// `percentiles_match_the_sort_based_definition`.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.history.is_empty() {
            return 0;
        }
        let mut scratch = self.scratch.borrow_mut();
        scratch.clear();
        scratch.extend_from_slice(&self.history);
        let rank = ((q.clamp(0.0, 1.0) * scratch.len() as f64).ceil() as usize)
            .clamp(1, scratch.len());
        let (_, nth, _) = scratch.select_nth_unstable(rank - 1);
        *nth
    }

    pub fn report(&self) -> BufferReport {
        BufferReport {
            final_samples: self.last(),
            peak_samples: self.peak,
            p50_samples: self.percentile(0.5),
            p90_samples: self.percentile(0.9),
            final_gb: samples_to_gb(self.last()),
            peak_gb: samples_to_gb(self.peak),
            rounds: self.history.len(),
        }
    }

    /// Export the occupancy summary as observability gauges. Values are
    /// exactly [`Self::report`]'s fields (pinned by
    /// `gauges_match_the_report`), so the Prometheus snapshot and the
    /// run report can never disagree about the same percentile.
    pub fn record_gauges(&self, rec: &mut dyn crate::obs::Recorder) {
        use crate::obs::Gauge;
        let r = self.report();
        rec.set_gauge(Gauge::BufferFinalSamples, r.final_samples as f64);
        rec.set_gauge(Gauge::BufferPeakSamples, r.peak_samples as f64);
        rec.set_gauge(Gauge::BufferP50Samples, r.p50_samples as f64);
        rec.set_gauge(Gauge::BufferP90Samples, r.p90_samples as f64);
    }
}

/// Convert buffered samples to "GB" at the paper's 3 KB/image.
///
/// The paper's Table II numbers are binary gigabytes (2³⁰ bytes):
/// T=1e5 · t=1.2s · S=100 · 3072 B = 34.33 — exactly their entry.
pub fn samples_to_gb(samples: u64) -> f64 {
    samples as f64 * SAMPLE_PAYLOAD_BYTES as f64 / (1u64 << 30) as f64
}

/// Reduction factor between two buffer footprints (Table IV's
/// "Persistence / Truncation" column); ∞-safe.
pub fn reduction_factor(persistence: u64, truncation: u64) -> f64 {
    persistence as f64 / truncation.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_peak_and_final() {
        let mut t = BufferTracker::new();
        for v in [10, 50, 30] {
            t.record(v);
        }
        let r = t.report();
        assert_eq!(r.final_samples, 30);
        assert_eq!(r.peak_samples, 50);
        assert_eq!(r.rounds, 3);
        assert_eq!(r.p50_samples, 30);
        assert_eq!(r.p90_samples, 50);
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let mut t = BufferTracker::new();
        for v in 1..=100u64 {
            t.record(v);
        }
        assert_eq!(t.percentile(0.5), 50);
        assert_eq!(t.percentile(0.9), 90);
        assert_eq!(t.percentile(0.0), 1); // floored at the first rank
        assert_eq!(t.percentile(1.0), 100);
        assert_eq!(BufferTracker::new().percentile(0.5), 0);
    }

    /// The pre-optimization implementation, kept as the semantic pin.
    fn percentile_by_sort(history: &[u64], q: f64) -> u64 {
        if history.is_empty() {
            return 0;
        }
        let mut sorted = history.to_vec();
        sorted.sort_unstable();
        let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize)
            .clamp(1, sorted.len());
        sorted[rank - 1]
    }

    #[test]
    fn percentiles_match_the_sort_based_definition() {
        // pseudo-random histories with duplicates and plateaus, across
        // the whole q range incl. out-of-range clamps
        let mut x = 0x9E37_79B9_7F4A_7C15u64;
        let mut histories: Vec<Vec<u64>> = vec![vec![], vec![7], vec![3, 3, 3, 3]];
        for len in [2usize, 5, 17, 100, 257] {
            let mut h = Vec::with_capacity(len);
            for _ in 0..len {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                h.push(x % 1000);
            }
            histories.push(h);
        }
        for h in &histories {
            let mut t = BufferTracker::new();
            for &v in h {
                t.record(v);
            }
            for q in [-0.5, 0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0, 2.0] {
                assert_eq!(
                    t.percentile(q),
                    percentile_by_sort(h, q),
                    "len={} q={q}",
                    h.len()
                );
            }
        }
    }

    #[test]
    fn percentile_scratch_is_reused_not_reallocated() {
        let mut t = BufferTracker::new();
        for v in 0..500u64 {
            t.record(v);
        }
        t.percentile(0.5); // warm the scratch
        let (cap, ptr) = {
            let s = t.scratch.borrow();
            (s.capacity(), s.as_ptr())
        };
        for q in [0.1, 0.5, 0.9, 1.0] {
            t.percentile(q);
        }
        let s = t.scratch.borrow();
        assert_eq!(s.capacity(), cap);
        assert_eq!(s.as_ptr(), ptr);
    }

    #[test]
    fn gauges_match_the_report() {
        use crate::obs::{Gauge, Recorder, TraceRecorder};
        let mut t = BufferTracker::new();
        for v in [10u64, 80, 40, 20, 60] {
            t.record(v);
        }
        let mut rec = TraceRecorder::new(false);
        t.record_gauges(&mut rec);
        let r = t.report();
        assert_eq!(rec.registry().gauge(Gauge::BufferFinalSamples), r.final_samples as f64);
        assert_eq!(rec.registry().gauge(Gauge::BufferPeakSamples), r.peak_samples as f64);
        assert_eq!(rec.registry().gauge(Gauge::BufferP50Samples), r.p50_samples as f64);
        assert_eq!(rec.registry().gauge(Gauge::BufferP90Samples), r.p90_samples as f64);
        // the no-op recorder accepts the same call (and ignores it)
        let mut noop = crate::obs::NoopRecorder;
        t.record_gauges(&mut noop);
        let _ = noop.enabled();
    }

    #[test]
    fn gb_conversion_matches_paper_scale() {
        // Table II: ResNet152 t=1.2s S=100 T=1e5 → 34.33 GB
        // samples ≈ T·t·S = 1.2e7 → ·3072B = 36.8 GB (same scale; the paper
        // rounds with 1024-based GB: 1.2e7·3072/2^30 = 34.33 GiB exactly).
        let samples = 100_000.0 * 1.2 * 100.0;
        let gib = samples * 3072.0 / (1u64 << 30) as f64;
        assert!((gib - 34.33).abs() < 0.05, "gib {gib}");
    }

    #[test]
    fn reduction_factor_safe() {
        assert_eq!(reduction_factor(1000, 10), 100.0);
        assert_eq!(reduction_factor(1000, 0), 1000.0);
    }
}
