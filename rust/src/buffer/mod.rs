//! Buffer policies + accounting (paper §IV "Limited memory and storage").
//!
//! [`policy::BufferPolicy`] is the user-facing knob (Persistence vs
//! Truncation) that maps onto the stream substrate's retention;
//! [`accounting::BufferTracker`] records per-round queue sizes across
//! devices and produces the numbers behind Fig. 8 (buffer growth), Table
//! IV (truncation reduction factors) and Table VI (GB saved).

pub mod accounting;
pub mod policy;

pub use accounting::{BufferReport, BufferTracker};
pub use policy::BufferPolicy;
