//! The two storage policies ScaDLES compares.


use crate::stream::Retention;

/// Device buffer policy (paper §IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BufferPolicy {
    /// *Stream Persistence*: keep every sample until consumed —
    /// O(S⁽ⁱ⁾·T) storage (Eqn. 2).
    #[default]
    Persistence,
    /// *Stream Truncation*: keep only ≈ one second of stream (the newest
    /// S⁽ⁱ⁾ samples) — O(S⁽ⁱ⁾) storage.
    Truncation,
}

impl BufferPolicy {
    /// Retention for a device whose streaming rate is `rate` samples/s.
    ///
    /// Truncation keeps `⌈rate⌉` records: "data in buffer exceeding the
    /// samples that just streamed in is simply discarded".
    pub fn retention(&self, rate: f64) -> Retention {
        match self {
            BufferPolicy::Persistence => Retention::Persist,
            BufferPolicy::Truncation => Retention::Truncate {
                keep: rate.ceil().max(1.0) as usize,
            },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BufferPolicy::Persistence => "persistence",
            BufferPolicy::Truncation => "truncation",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn persistence_unbounded() {
        assert_eq!(BufferPolicy::Persistence.retention(300.0), Retention::Persist);
    }

    #[test]
    fn truncation_keeps_one_second_of_stream() {
        assert_eq!(
            BufferPolicy::Truncation.retention(37.4),
            Retention::Truncate { keep: 38 }
        );
        assert_eq!(
            BufferPolicy::Truncation.retention(0.2),
            Retention::Truncate { keep: 1 }
        );
    }
}
