//! The two storage policies ScaDLES compares.


use crate::stream::Retention;

/// Device buffer policy (paper §IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BufferPolicy {
    /// *Stream Persistence*: keep every sample until consumed —
    /// O(S⁽ⁱ⁾·T) storage (Eqn. 2).
    #[default]
    Persistence,
    /// *Stream Truncation*: keep only ≈ one second of stream (the newest
    /// S⁽ⁱ⁾ samples) — O(S⁽ⁱ⁾) storage.
    Truncation,
}

impl BufferPolicy {
    /// Retention for a device whose **effective** streaming rate is
    /// `rate` samples/s — the rate as currently flowing (nominal ×
    /// dynamics factor), not the statically configured one, so that
    /// Truncation keeps ≈ 1 s of the stream as it actually arrives.
    /// Callers re-derive retention whenever the effective rate moves
    /// (`Device::apply_dynamics`): a rising rate widens the window, a
    /// falling one narrows it.
    ///
    /// Truncation keeps `⌈rate⌉` records: "data in buffer exceeding the
    /// samples that just streamed in is simply discarded". The window is
    /// floored at **one** record even when the effective rate drops to 0
    /// (a churned-out or stalled stream): `keep` can never underflow to
    /// 0, the newest record survives, and the backlog simply drains as
    /// the consumer polls — the device sits rounds out instead of
    /// panicking on an empty window.
    pub fn retention(&self, rate: f64) -> Retention {
        match self {
            BufferPolicy::Persistence => Retention::Persist,
            BufferPolicy::Truncation => Retention::Truncate {
                keep: rate.ceil().max(1.0) as usize,
            },
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BufferPolicy::Persistence => "persistence",
            BufferPolicy::Truncation => "truncation",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn persistence_unbounded() {
        assert_eq!(BufferPolicy::Persistence.retention(300.0), Retention::Persist);
    }

    #[test]
    fn truncation_keeps_one_second_of_stream() {
        assert_eq!(
            BufferPolicy::Truncation.retention(37.4),
            Retention::Truncate { keep: 38 }
        );
        assert_eq!(
            BufferPolicy::Truncation.retention(0.2),
            Retention::Truncate { keep: 1 }
        );
    }

    #[test]
    fn truncation_window_follows_a_rising_effective_rate() {
        // diurnal peak: nominal 100/s boosted 3x — the window must cover
        // one second of the boosted stream, not the nominal one
        let nominal = BufferPolicy::Truncation.retention(100.0);
        let boosted = BufferPolicy::Truncation.retention(100.0 * 3.0);
        assert_eq!(nominal, Retention::Truncate { keep: 100 });
        assert_eq!(boosted, Retention::Truncate { keep: 300 });
    }

    #[test]
    fn truncation_window_follows_a_falling_effective_rate() {
        // burst trough: 100/s faded to a quarter — keep shrinks with it
        assert_eq!(
            BufferPolicy::Truncation.retention(100.0 * 0.25),
            Retention::Truncate { keep: 25 }
        );
        // effective rate 0 (churned out / stalled): the window floors at
        // one record — no zero-keep underflow, the buffer just drains
        assert_eq!(
            BufferPolicy::Truncation.retention(0.0),
            Retention::Truncate { keep: 1 }
        );
        assert_eq!(BufferPolicy::Persistence.retention(0.0), Retention::Persist);
    }
}
