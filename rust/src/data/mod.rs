//! Streaming dataset substrate: synthetic CIFAR-like data + label skew.
//!
//! The sandbox has no CIFAR10/100 download, so the paper's datasets are
//! substituted with a deterministic synthetic family (DESIGN.md §5): each
//! class has a smooth random "pattern" image and samples are
//! `pattern[label] + noise`. This preserves exactly what the paper's
//! experiments exercise — class structure that a small CNN can learn, and
//! label-skew (non-IID) partitioning across devices — while every sample
//! is regenerable from a `u64` seed, which is what lets the stream broker
//! buffer millions of records without storing pixels.

pub mod dataset;
pub mod emd;
pub mod partitioner;
pub mod synthetic;

pub use dataset::{materialize, EvalSet};
pub use emd::mean_skew;
pub use partitioner::LabelMap;
pub use synthetic::Synthetic;
