//! Batch materialization + held-out evaluation sets.

use super::synthetic::{Synthetic, ELEMS};
use crate::stream::Record;

/// Turn polled stream records into a training batch `(x, y)`.
///
/// `x` is `records.len() · 3072` floats (NHWC row-major), `y` the labels.
/// Pixels are regenerated from each record's seed — the streaming buffers
/// never hold pixels (see [`crate::stream::record::Record`]).
pub fn materialize(data: &Synthetic, records: &[Record]) -> (Vec<f32>, Vec<i32>) {
    let mut x = vec![0f32; records.len() * ELEMS];
    let mut y = Vec::with_capacity(records.len());
    for (i, r) in records.iter().enumerate() {
        data.sample_into(r.label, r.seed, &mut x[i * ELEMS..(i + 1) * ELEMS]);
        y.push(r.label as i32);
    }
    (x, y)
}

/// A fixed held-out evaluation set, balanced across classes.
///
/// Seeds live in a reserved namespace (high bit set) so the training
/// stream can never emit an eval sample.
#[derive(Debug, Clone)]
pub struct EvalSet {
    x: Vec<f32>,
    y: Vec<i32>,
}

impl EvalSet {
    pub fn new(data: &Synthetic, per_class: usize) -> Self {
        let ncls = data.num_classes();
        let n = ncls * per_class;
        let mut x = vec![0f32; n * ELEMS];
        let mut y = Vec::with_capacity(n);
        for cls in 0..ncls {
            for j in 0..per_class {
                let i = cls * per_class + j;
                let seed = (1u64 << 63) | ((cls as u64) << 32) | j as u64;
                data.sample_into(cls as u32, seed, &mut x[i * ELEMS..(i + 1) * ELEMS]);
                y.push(cls as i32);
            }
        }
        Self { x, y }
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Iterate `(x_chunk, y_chunk)` slices of at most `chunk` samples —
    /// sized to the eval artifact's bucket.
    pub fn chunks(&self, chunk: usize) -> impl Iterator<Item = (&[f32], &[i32])> {
        self.y
            .chunks(chunk)
            .zip(self.x.chunks(chunk * ELEMS))
            .map(|(y, x)| (x, y))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(label: u32, seed: u64) -> Record {
        Record { offset: 0, timestamp_us: 0, label, seed }
    }

    #[test]
    fn materialize_shapes_and_labels() {
        let d = Synthetic::standard(10, 42);
        let recs: Vec<Record> = (0..7).map(|i| rec(i % 10, i as u64)).collect();
        let (x, y) = materialize(&d, &recs);
        assert_eq!(x.len(), 7 * ELEMS);
        assert_eq!(y, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn materialize_matches_direct_generation() {
        let d = Synthetic::standard(10, 42);
        let (x, _) = materialize(&d, &[rec(4, 77)]);
        assert_eq!(x, d.sample(4, 77));
    }

    #[test]
    fn eval_set_balanced_and_chunked() {
        let d = Synthetic::standard(10, 42);
        let ev = EvalSet::new(&d, 3);
        assert_eq!(ev.len(), 30);
        let total: usize = ev.chunks(8).map(|(_, y)| y.len()).sum();
        assert_eq!(total, 30);
        let (x0, y0) = ev.chunks(8).next().unwrap();
        assert_eq!(x0.len(), y0.len() * ELEMS);
    }

    #[test]
    fn eval_seeds_disjoint_from_stream_seeds() {
        // stream seeds come from Pcg64::next_u64 which can produce any u64;
        // eval namespace is (1<<63)|... — collisions are possible in theory
        // but the *label+seed* pair regenerates identical pixels anyway, so
        // what matters is determinism:
        let d = Synthetic::standard(10, 42);
        let e1 = EvalSet::new(&d, 2);
        let e2 = EvalSet::new(&d, 2);
        assert_eq!(e1.x, e2.x);
    }
}
