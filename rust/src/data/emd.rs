//! Earth mover's distance over label distributions.
//!
//! Zhao et al. (cited in paper §III-A) quantify non-IID-ness as the EMD
//! between each device's label distribution and the population
//! distribution; weight divergence — and hence accuracy loss — grows with
//! it. For categorical distributions over the same support with unit
//! ground distance, EMD reduces to total variation:
//! `EMD(p, q) = ½ Σ|p_c − q_c|`.
//!
//! The harness uses this to report how skewed each configuration is
//! (IID ⇒ 0; the paper's 1-label-per-device CIFAR10 split ⇒ 0.9).

use crate::data::partitioner::LabelMap;

/// ½ Σ|p − q| over aligned categorical distributions.
pub fn emd(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len(), "distributions must share support");
    0.5 * p.iter().zip(q).map(|(a, b)| (a - b).abs()).sum::<f64>()
}

/// Normalize a histogram into a distribution (empty → uniform-free zero).
pub fn normalize(hist: &[f64]) -> Vec<f64> {
    let total: f64 = hist.iter().sum();
    if total <= 0.0 {
        return vec![0.0; hist.len()];
    }
    hist.iter().map(|h| h / total).collect()
}

/// Label distribution of one device under a [`LabelMap`] (uniform over its
/// assigned labels — the stream producer samples uniformly).
pub fn device_distribution(map: &LabelMap, device: usize, num_classes: usize) -> Vec<f64> {
    let labels = map.device_labels(device, num_classes);
    let mut p = vec![0.0; num_classes];
    for l in &labels {
        p[*l as usize] += 1.0 / labels.len() as f64;
    }
    p
}

/// Mean device-to-population EMD for a cluster — the skew number Zhao et
/// al. correlate with accuracy loss. Population = uniform over classes
/// (our synthetic streams are class-balanced in aggregate).
pub fn mean_skew(map: &LabelMap, devices: usize, num_classes: usize) -> f64 {
    let pop = vec![1.0 / num_classes as f64; num_classes];
    (0..devices)
        .map(|i| emd(&device_distribution(map, i, num_classes), &pop))
        .sum::<f64>()
        / devices.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_distributions_are_zero() {
        let p = vec![0.25; 4];
        assert_eq!(emd(&p, &p), 0.0);
    }

    #[test]
    fn disjoint_distributions_are_one() {
        assert_eq!(emd(&[1.0, 0.0], &[0.0, 1.0]), 1.0);
    }

    #[test]
    fn iid_cluster_has_zero_skew() {
        assert_eq!(mean_skew(&LabelMap::Iid, 16, 10), 0.0);
    }

    #[test]
    fn paper_cifar10_split_has_skew_point_nine() {
        // 1 label/device over 10 classes: EMD = ½(|1−.1| + 9·|0−.1|) = 0.9
        let (map, devs) = LabelMap::paper_cifar10();
        let s = mean_skew(&map, devs, 10);
        assert!((s - 0.9).abs() < 1e-12, "skew {s}");
    }

    #[test]
    fn paper_cifar100_split_has_skew_point_ninety_six() {
        // 4 labels/device over 100 classes: ½(4·|.25−.01| + 96·.01) = 0.96
        let (map, devs) = LabelMap::paper_cifar100();
        let s = mean_skew(&map, devs, 100);
        assert!((s - 0.96).abs() < 1e-12, "skew {s}");
    }

    #[test]
    fn skew_decreases_with_labels_per_device() {
        let s1 = mean_skew(&LabelMap::NonIid { labels_per_device: 1 }, 10, 10);
        let s5 = mean_skew(&LabelMap::NonIid { labels_per_device: 5 }, 10, 10);
        assert!(s5 < s1);
    }

    #[test]
    fn normalize_handles_empty() {
        assert_eq!(normalize(&[0.0, 0.0]), vec![0.0, 0.0]);
        assert_eq!(normalize(&[2.0, 2.0]), vec![0.5, 0.5]);
    }
}
