//! Label partitioning: IID vs non-IID (label-skew) device data.
//!
//! The paper induces non-IID distributions "by mapping a subset of labels
//! to a unique device": CIFAR10 on 10 devices with 1 label each, CIFAR100
//! on 25 devices with 4 labels each (Table III). [`LabelMap`] reproduces
//! exactly that mapping and generalizes it to any (devices, classes,
//! labels-per-device) combination.


/// How training labels are distributed across devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LabelMap {
    /// Every device streams every class (conventional DDL assumption).
    Iid,
    /// Label skew: each device streams only `labels_per_device` classes,
    /// assigned contiguously round-robin (device i gets labels
    /// `[i·k mod C, ..., (i·k + k − 1) mod C]`).
    NonIid { labels_per_device: usize },
}

impl LabelMap {
    /// Paper Table III presets.
    pub fn paper_cifar10() -> (Self, usize) {
        (LabelMap::NonIid { labels_per_device: 1 }, 10)
    }
    pub fn paper_cifar100() -> (Self, usize) {
        (LabelMap::NonIid { labels_per_device: 4 }, 25)
    }

    /// The class labels device `device` streams, out of `num_classes`.
    pub fn device_labels(&self, device: usize, num_classes: usize) -> Vec<u32> {
        match *self {
            LabelMap::Iid => (0..num_classes as u32).collect(),
            LabelMap::NonIid { labels_per_device } => {
                let k = labels_per_device.clamp(1, num_classes);
                (0..k)
                    .map(|j| ((device * k + j) % num_classes) as u32)
                    .collect()
            }
        }
    }

    /// True when every class is covered by at least one of `devices`.
    pub fn covers_all_classes(&self, devices: usize, num_classes: usize) -> bool {
        let mut seen = vec![false; num_classes];
        for d in 0..devices {
            for l in self.device_labels(d, num_classes) {
                seen[l as usize] = true;
            }
        }
        seen.into_iter().all(|b| b)
    }

    pub fn is_iid(&self) -> bool {
        matches!(self, LabelMap::Iid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iid_gives_all_labels() {
        assert_eq!(LabelMap::Iid.device_labels(3, 10).len(), 10);
    }

    #[test]
    fn paper_cifar10_mapping() {
        let (m, devs) = LabelMap::paper_cifar10();
        // 10 devices, single distinct label each
        let mut seen = vec![];
        for d in 0..devs {
            let ls = m.device_labels(d, 10);
            assert_eq!(ls.len(), 1);
            seen.push(ls[0]);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<u32>>());
    }

    #[test]
    fn paper_cifar100_mapping() {
        let (m, devs) = LabelMap::paper_cifar100();
        // 25 devices × 4 labels cover all 100 classes exactly once
        let mut seen = vec![];
        for d in 0..devs {
            let ls = m.device_labels(d, 100);
            assert_eq!(ls.len(), 4);
            seen.extend(ls);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..100).collect::<Vec<u32>>());
        assert!(m.covers_all_classes(devs, 100));
    }

    #[test]
    fn wraps_when_devices_exceed_classes() {
        let m = LabelMap::NonIid { labels_per_device: 1 };
        assert_eq!(m.device_labels(12, 10), vec![2]);
    }

    #[test]
    fn coverage_detects_gaps() {
        let m = LabelMap::NonIid { labels_per_device: 1 };
        assert!(!m.covers_all_classes(5, 10));
        assert!(m.covers_all_classes(10, 10));
    }
}
