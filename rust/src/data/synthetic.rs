//! Deterministic synthetic CIFAR-like image generator.

use crate::rng::Pcg64;

/// Image geometry matching the models' input (32·32·3, NHWC).
pub const H: usize = 32;
pub const W: usize = 32;
pub const C: usize = 3;
pub const ELEMS: usize = H * W * C;

/// Coarse pattern grid; upsampled bilinearly to 32×32 so class patterns
/// are smooth blobs a small CNN can separate.
const GRID: usize = 4;

/// Synthetic class-pattern dataset (`synth10`, `synth100`, ...).
#[derive(Debug, Clone)]
pub struct Synthetic {
    num_classes: usize,
    noise: f32,
    /// Per-class 32×32×3 patterns, precomputed.
    patterns: Vec<Vec<f32>>,
}

impl Synthetic {
    /// `seed` fixes the class patterns; `noise` is the per-sample Gaussian
    /// std (1.1 gives ~synthetic-CIFAR difficulty for the tiny models:
    /// linear heads plateau below 100%, convnets separate classes in a
    /// few dozen rounds — leaving headroom for non-IID/compression drops).
    pub fn new(num_classes: usize, seed: u64, noise: f32) -> Self {
        let patterns = (0..num_classes)
            .map(|cls| Self::make_pattern(seed, cls as u64))
            .collect();
        Self {
            num_classes,
            noise,
            patterns,
        }
    }

    /// Standard configuration used by experiments: noise 1.1.
    pub fn standard(num_classes: usize, seed: u64) -> Self {
        Self::new(num_classes, seed, 1.1)
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn make_pattern(seed: u64, cls: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed ^ 0x5EED_5EED, cls + 1);
        // coarse grid per channel
        let mut grid = [[[0f32; GRID]; GRID]; C];
        for ch in grid.iter_mut() {
            for row in ch.iter_mut() {
                for v in row.iter_mut() {
                    *v = rng.uniform(-1.0, 1.0) as f32;
                }
            }
        }
        // bilinear upsample to H×W
        let mut out = vec![0f32; ELEMS];
        for y in 0..H {
            for x in 0..W {
                let gy = y as f32 * (GRID - 1) as f32 / (H - 1) as f32;
                let gx = x as f32 * (GRID - 1) as f32 / (W - 1) as f32;
                let (y0, x0) = (gy as usize, gx as usize);
                let (y1, x1) = ((y0 + 1).min(GRID - 1), (x0 + 1).min(GRID - 1));
                let (fy, fx) = (gy - y0 as f32, gx - x0 as f32);
                for c in 0..C {
                    let g = &grid[c];
                    let v = g[y0][x0] * (1.0 - fy) * (1.0 - fx)
                        + g[y0][x1] * (1.0 - fy) * fx
                        + g[y1][x0] * fy * (1.0 - fx)
                        + g[y1][x1] * fy * fx;
                    out[(y * W + x) * C + c] = v;
                }
            }
        }
        out
    }

    /// Generate the pixels of one sample into `out` (length [`ELEMS`]).
    pub fn sample_into(&self, label: u32, seed: u64, out: &mut [f32]) {
        debug_assert_eq!(out.len(), ELEMS);
        let pattern = &self.patterns[label as usize % self.num_classes];
        let mut rng = Pcg64::new(seed, 0xDA7A);
        for (o, &p) in out.iter_mut().zip(pattern.iter()) {
            *o = p + self.noise * rng.normal() as f32;
        }
    }

    /// Allocating variant of [`sample_into`].
    pub fn sample(&self, label: u32, seed: u64) -> Vec<f32> {
        let mut out = vec![0f32; ELEMS];
        self.sample_into(label, seed, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let s = Synthetic::standard(10, 42);
        assert_eq!(s.sample(3, 99), s.sample(3, 99));
    }

    #[test]
    fn seeds_vary_samples_within_class() {
        let s = Synthetic::standard(10, 42);
        assert_ne!(s.sample(3, 1), s.sample(3, 2));
    }

    #[test]
    fn classes_are_separated() {
        // mean intra-class distance must be well below inter-class distance
        let s = Synthetic::standard(10, 42);
        let a1 = s.sample(0, 1);
        let a2 = s.sample(0, 2);
        let b = s.sample(1, 3);
        let dist = |u: &[f32], v: &[f32]| -> f32 {
            u.iter().zip(v).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt()
        };
        let intra = dist(&a1, &a2);
        let inter = (dist(&a1, &b) + dist(&a2, &b)) / 2.0;
        assert!(inter > intra * 1.05, "inter {inter} vs intra {intra}");
    }

    #[test]
    fn pattern_seed_changes_everything() {
        let s1 = Synthetic::standard(10, 1);
        let s2 = Synthetic::standard(10, 2);
        assert_ne!(s1.sample(0, 5), s2.sample(0, 5));
    }

    #[test]
    fn values_bounded_sanely() {
        let s = Synthetic::standard(100, 42);
        let x = s.sample(57, 1234);
        assert!(x.iter().all(|v| v.abs() < 6.0));
    }
}
