//! # ScaDLES — Scalable Deep Learning over Streaming data at the Edge
//!
//! A three-layer Rust + JAX + Pallas reproduction of *ScaDLES* (Tyagi &
//! Swany, IEEE BigData 2022): a distributed-training coordinator for
//! online learning over heterogeneous data streams at the edge.
//!
//! The crate is **Layer 3**: the coordination contribution of the paper —
//! stream-rate-proportional batching, weighted gradient aggregation
//! (Eqn. 4), stream buffer policies (persistence/truncation), adaptive
//! Top-k gradient compression, and randomized data injection for non-IID
//! data — plus every substrate the paper depends on (a Kafka-like stream
//! broker, a streaming dataset, analytic cluster/network simulators, a
//! conventional-DDL baseline).
//!
//! On top of the paper's streaming-rate heterogeneity the crate models
//! **systems heterogeneity**: each device owns a
//! [`config::DeviceProfile`] (compute class, uplink/downlink bandwidth,
//! memory budget) sampled from a named [`config::HeteroPreset`] scenario
//! (`k80-homogeneous` default, `uniform`, `two-tier`,
//! `lognormal-compute`, `constrained-uplink`). Sampling flows through
//! fixed per-device [`rng::Pcg64`] substreams, so every scenario keeps
//! the engine's bitwise-determinism guarantee at any worker-pool width;
//! per-round straggler attribution (stream-wait vs compute vs sync)
//! lands in [`metrics::Timeline`]. See `examples/two_tier_cluster.rs`.
//!
//! The time axis is first-class too: a [`config::DynamicsPreset`]
//! scenario (`static` default, `diurnal`, `burst`, `churn`, `linkfade`,
//! `trace:PATH`, composable with `+`) drives the [`dynamics`] engine,
//! which modulates per-device streaming rates, link bandwidths and
//! cluster membership as virtual time advances — deterministic at any
//! worker-pool width, with `static` reproducing the frozen-profile
//! engine bitwise. See `examples/diurnal_burst.rs`.
//!
//! *Who commits* a round is pluggable as well: a [`config::SyncPreset`]
//! names a [`coordinator::SyncPolicy`] for the round engine — `bsp`
//! (the paper's fully-synchronous regime, the bitwise-identical
//! default), `ksync:frac` (semi-sync commit on the fastest `⌈frac·n⌉`
//! devices, laggard gradients riding the error-feedback residual),
//! `stale:s` (bounded staleness with discounted late contributions) and
//! `local:h` (FedAvg-style local SGD with sample-weighted parameter
//! averaging). See `examples/ksync_two_tier.rs`.
//!
//! *What the survivors cost on the wire* is a [`config::WirePreset`]:
//! `--wire f32` (the default — full-precision survivor pairs, bitwise
//! the unwired engine), `q8` or `q4` quantize Top-k survivor values
//! with QSGD's unbiased stochastic-uniform rule against a per-row
//! scale ([`compress::QuantizedGrad`]) and delta-varint the indices;
//! error feedback banks the quantization error, sync time and the
//! run's measured `sync_bytes` are priced from the exact encoded bits
//! ([`simulate::NetworkModel::quantized_sync_time`]), and per-worker
//! wire RNGs live on fixed [`rng::Pcg64`] substreams so the codec is
//! deterministic at any pool width and across checkpoint restores.
//!
//! Looking *inside* a round is the [`obs`] layer: `--trace FILE[,fmt]`
//! records per-device, per-phase **spans** on the simulator's virtual
//! clock (drain → train → compress/encode → sync, plus a coordinator
//! track) into Chrome trace-event JSON (open in Perfetto) or JSONL,
//! and `--metrics FILE` snapshots a typed counter/gauge registry
//! (sync bits, floats sent, fault/dynamics tallies, buffer occupancy
//! percentiles, EF residual mass) as Prometheus text. The virtual-time
//! event stream is bitwise deterministic at any worker-pool width and
//! across checkpoint kill/resume; with tracing off the no-op recorder
//! adds zero steady-state allocations. See `examples/traced_run.rs`.
//!
//! *Whether a round's result counts* is the resilient coordinator
//! runtime ([`coordinator::CoordinatorRuntime`]): a rendezvous /
//! heartbeat / witness-quorum state machine whose control messages move
//! over a [`transport`] — an in-proc virtual-time queue, optionally
//! wrapped by deterministic transport-fault injection (`--net
//! lossy:…|dup:…|partition:…`, pure in `(seed, device, round)`), or a
//! minimal TCP transport behind `repro serve` / `repro join` for a
//! multi-process localhost demo. Missed-heartbeat devices are evicted
//! from the round's barrier; a failed witness quorum replays the round
//! from an in-memory snapshot; and a lossy run's trained model stays
//! bitwise identical to the lossless run at any worker-pool width. See
//! `examples/quorum_lossy.rs`.
//!
//! Layers 1–2 (Pallas kernels + JAX models) are AOT-lowered to HLO text at
//! build time (`make artifacts`) and executed through the PJRT CPU client
//! by [`runtime`]. Python never runs on the training path.
//!
//! Quick tour (see `examples/quickstart.rs` for the runnable version):
//!
//! ```no_run
//! use scadles::config::ExperimentConfig;
//! use scadles::coordinator::Trainer;
//!
//! let cfg = ExperimentConfig::builder("mlp_c10")
//!     .devices(4)
//!     .rounds(20)
//!     .build()
//!     .unwrap();
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let out = trainer.run().unwrap();
//! println!("final loss {:.3}", out.report.final_train_loss);
//! ```

pub mod buffer;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dynamics;
pub mod faults;
pub mod harness;
pub mod injection;
pub mod metrics;
pub mod obs;
pub mod rng;
pub mod runtime;
pub mod simulate;
pub mod stream;
pub mod transport;
pub mod util;

/// Crate-wide result type (anyhow for ergonomic error context).
pub type Result<T> = anyhow::Result<T>;
