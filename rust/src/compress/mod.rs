//! Gradient compression (paper §IV "High communication cost", §V-G).
//!
//! ScaDLES uses **adaptive Top-k sparsification**: each round, every
//! device's gradient is masked to its top `CR·d` magnitudes (the L1 Pallas
//! `topk` kernel applies the mask and returns `|g|²`/`|Topk(g)|²`), and the
//! compressed tensor is exchanged only while the EWMA of the relative
//! compression error stays below δ; otherwise the dense gradient is sent.
//!
//! * [`topk`]     — O(d) k-th-magnitude threshold selection (select-nth,
//!   optionally over a reusable [`SelectScratch`]) plus a pure-Rust
//!   mask/stats fallback mirroring the Pallas kernel.
//! * [`sparse`]   — [`SparseGrad`], the coordinate form the mask phase
//!   emits directly so the round engine can aggregate in O(nnz).
//! * [`adaptive`] — the EWMA-gated send rule.
//! * [`cnc`]      — Compression-to-No-Compression ratio + floats-sent
//!   accounting (Table V's metrics).
//! * [`schemes`]  — `None` / `StaticTopk` / `AdaptiveTopk` policy objects
//!   the coordinator drives.
//! * [`wire`]     — [`QuantizedGrad`], the q8/q4 stochastic-uniform wire
//!   format (`--wire`) with exact encoded-bit accounting.

pub mod adaptive;
pub mod baselines;
pub mod cnc;
pub mod feedback;
pub mod schemes;
pub mod sparse;
pub mod topk;
pub mod wire;

pub use adaptive::AdaptiveGate;
pub use baselines::{fp16_roundtrip, qsgd, terngrad, Encoded};
pub use cnc::CncCounter;
pub use feedback::ErrorFeedback;
pub use schemes::{CompressionDecision, CompressionScheme};
pub use sparse::SparseGrad;
pub use topk::{
    mask_stats_native, mask_stats_only, threshold_for_ratio, threshold_for_ratio_select_nth_with,
    threshold_for_ratio_with, topk_threshold, topk_threshold_select_nth_with,
    topk_threshold_with, SelectScratch,
};
pub use wire::{delta_index_bits, quantized_value_bits, varint_bits, QuantizedGrad};
