//! Top-k threshold selection + native mask/stats fallback.
//!
//! The magnitude threshold is found with `select_nth_unstable` — O(d)
//! average, no full sort — in the coordinator; the Pallas kernel (or
//! [`mask_stats_native`], its bit-exact Rust mirror used by tests and the
//! kernel-ablation bench) then applies the mask in one streaming pass.

/// Reusable magnitude buffer for threshold selection.
///
/// `select_nth_unstable` is in-place, so the only allocation in
/// [`topk_threshold`] is the d-length magnitude copy — 3.2 MB per
/// device-round at mlp_c10's d = 820 874. Workers own one of these and
/// route through [`topk_threshold_with`], which refills the same buffer
/// each round; the compressed steady state allocates nothing for
/// selection (pinned by `tests/alloc_steady_state.rs`).
#[derive(Debug, Clone, Default)]
pub struct SelectScratch {
    buf: Vec<f32>,
}

impl SelectScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for a gradient dimension.
    pub fn with_capacity(d: usize) -> Self {
        Self { buf: Vec::with_capacity(d) }
    }
}

/// k-th largest magnitude of `g` (the mask keeps `|g_j| >= thresh`).
/// `k = 0` returns +inf (nothing survives); `k >= d` returns 0 (all pass).
pub fn topk_threshold(g: &[f32], k: usize) -> f32 {
    topk_threshold_with(g, k, &mut SelectScratch::new())
}

/// [`topk_threshold`] over a caller-owned magnitude buffer — identical
/// result (same data, same deterministic select-nth), no allocation once
/// the scratch capacity has reached `g.len()`.
pub fn topk_threshold_with(g: &[f32], k: usize, scratch: &mut SelectScratch) -> f32 {
    let d = g.len();
    if k == 0 || d == 0 {
        return f32::INFINITY;
    }
    if k >= d {
        return 0.0;
    }
    scratch.buf.clear();
    scratch.buf.extend(g.iter().map(|v| v.abs()));
    // nth element in descending order = index k-1
    let (_, nth, _) = scratch.buf.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
    *nth
}

/// Threshold for keeping a `ratio` fraction (CR) of `g`'s elements.
pub fn threshold_for_ratio(g: &[f32], ratio: f64) -> (usize, f32) {
    threshold_for_ratio_with(g, ratio, &mut SelectScratch::new())
}

/// [`threshold_for_ratio`] over a caller-owned selection scratch.
pub fn threshold_for_ratio_with(
    g: &[f32],
    ratio: f64,
    scratch: &mut SelectScratch,
) -> (usize, f32) {
    let k = ((g.len() as f64 * ratio).ceil() as usize).clamp(1, g.len().max(1));
    (k, topk_threshold_with(g, k, scratch))
}

/// Native mirror of the Pallas `topk_mask_stats` kernel: zero sub-threshold
/// entries in place and return `(|g|², |Topk(g)|², nnz)`.
pub fn mask_stats_native(g: &mut [f32], thresh: f32) -> (f64, f64, usize) {
    let mut norm2 = 0f64;
    let mut knorm2 = 0f64;
    let mut nnz = 0usize;
    for v in g.iter_mut() {
        let x = *v as f64;
        norm2 += x * x;
        if v.abs() >= thresh {
            knorm2 += x * x;
            nnz += 1;
        } else {
            *v = 0.0;
        }
    }
    (norm2, knorm2, nnz)
}

/// Stats-only pass of [`mask_stats_native`]: same `(|g|², |Topk(g)|²,
/// nnz)` — bit for bit, same accumulation order — without zeroing the
/// input. The sparse fast path runs this first so the survivor count is
/// known before [`super::SparseGrad::fill_from_threshold`] reserves,
/// and keeps `g` intact as the *corrected* gradient the error-feedback
/// residual is taken against.
pub fn mask_stats_only(g: &[f32], thresh: f32) -> (f64, f64, usize) {
    let mut norm2 = 0f64;
    let mut knorm2 = 0f64;
    let mut nnz = 0usize;
    for v in g {
        let x = *v as f64;
        norm2 += x * x;
        if v.abs() >= thresh {
            knorm2 += x * x;
            nnz += 1;
        }
    }
    (norm2, knorm2, nnz)
}

/// Sparse view of a masked gradient: (indices, values) of survivors.
/// What actually crosses the network at 8 bytes/element. `nnz_hint`
/// (known from the mask-stats pass) sizes the output vectors in one
/// reserve instead of growing from empty; a wrong hint only costs the
/// usual doubling growth. Thin wrapper over
/// [`super::SparseGrad::fill_from_masked`] — one implementation of the
/// non-zero scan, two shapes of output.
pub fn sparsify(g: &[f32], nnz_hint: usize) -> (Vec<u32>, Vec<f32>) {
    // with_capacity (exact) rather than a bare reserve (amortized, may
    // round up): the capacity-respecting contract is part of the API
    let mut s = super::SparseGrad::with_capacity(nnz_hint);
    s.fill_from_masked(g, nnz_hint);
    (s.idx, s.val)
}

/// Reassemble a dense gradient from its sparse view.
pub fn densify(d: usize, idx: &[u32], val: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; d];
    for (&i, &v) in idx.iter().zip(val) {
        out[i as usize] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_selects_exactly_k_distinct_magnitudes() {
        let g = [0.1f32, -5.0, 3.0, 0.2, -0.4, 2.0];
        let t = topk_threshold(&g, 3);
        assert_eq!(t, 2.0);
        let kept = g.iter().filter(|v| v.abs() >= t).count();
        assert_eq!(kept, 3);
    }

    #[test]
    fn threshold_edges() {
        let g = [1f32, 2.0, 3.0];
        assert_eq!(topk_threshold(&g, 0), f32::INFINITY);
        assert_eq!(topk_threshold(&g, 3), 0.0);
        assert_eq!(topk_threshold(&[], 1), f32::INFINITY);
    }

    #[test]
    fn ratio_keeps_cr_fraction() {
        // distinct magnitudes 1..=1000 with alternating signs
        let g: Vec<f32> = (0..1000)
            .map(|i| (i + 1) as f32 * if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let (k, t) = threshold_for_ratio(&g, 0.1);
        assert_eq!(k, 100);
        let kept = g.iter().filter(|v| v.abs() >= t).count();
        assert_eq!(kept, 100);
    }

    #[test]
    fn mask_stats_match_definition() {
        let mut g = vec![1f32, -2.0, 0.5, 4.0];
        let (n2, k2, nnz) = mask_stats_native(&mut g, 2.0);
        assert_eq!(n2, 1.0 + 4.0 + 0.25 + 16.0);
        assert_eq!(k2, 4.0 + 16.0);
        assert_eq!(nnz, 2);
        assert_eq!(g, vec![0.0, -2.0, 0.0, 4.0]);
    }

    #[test]
    fn sparsify_roundtrip() {
        let g = vec![0f32, 3.0, 0.0, -1.0, 0.0];
        let (i, v) = sparsify(&g, 2);
        assert_eq!(i, vec![1, 3]);
        assert_eq!(densify(5, &i, &v), g);
    }

    #[test]
    fn sparsify_respects_the_capacity_hint() {
        let g = vec![0f32, 3.0, 0.0, -1.0, 0.0, 2.5];
        // the hint pre-sizes the vectors (with_capacity guarantees *at
        // least* n — exactness is a std implementation detail we don't
        // pin); an exact hint must not trigger any growth reallocation,
        // which we observe as capacity staying at its initial value
        let (i, v) = sparsify(&g, 3);
        assert_eq!(i.len(), 3);
        let hinted_cap = crate::compress::SparseGrad::with_capacity(3).idx.capacity();
        assert_eq!(i.capacity(), hinted_cap);
        assert_eq!(v.capacity(), hinted_cap);
        // an under-hint still produces the right answer (vec growth)
        let (i2, v2) = sparsify(&g, 0);
        assert_eq!(i2, i);
        assert_eq!(v2, v);
    }

    #[test]
    fn scratch_reuse_matches_the_allocating_path() {
        let g: Vec<f32> = (0..500)
            .map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.3)
            .collect();
        let mut scratch = SelectScratch::with_capacity(g.len());
        for k in [1usize, 3, 50, 499, 500, 600] {
            assert_eq!(
                topk_threshold(&g, k).to_bits(),
                topk_threshold_with(&g, k, &mut scratch).to_bits(),
                "k={k}"
            );
        }
        for ratio in [0.001, 0.1, 0.5, 1.0] {
            assert_eq!(
                threshold_for_ratio(&g, ratio),
                threshold_for_ratio_with(&g, ratio, &mut scratch),
                "ratio={ratio}"
            );
        }
        // warm scratch never reallocates
        let (cap, ptr) = (scratch.buf.capacity(), scratch.buf.as_ptr());
        topk_threshold_with(&g, 10, &mut scratch);
        assert_eq!(scratch.buf.capacity(), cap);
        assert_eq!(scratch.buf.as_ptr(), ptr);
    }

    #[test]
    fn stats_only_matches_the_masking_pass_bitwise() {
        let g: Vec<f32> = (0..300).map(|i| (i as f32 - 150.0) * 0.01).collect();
        for thresh in [0.0f32, 0.4, 1.2, f32::INFINITY] {
            let (n2a, k2a, nnza) = mask_stats_only(&g, thresh);
            let mut masked = g.clone();
            let (n2b, k2b, nnzb) = mask_stats_native(&mut masked, thresh);
            assert_eq!(n2a.to_bits(), n2b.to_bits(), "thresh={thresh}");
            assert_eq!(k2a.to_bits(), k2b.to_bits(), "thresh={thresh}");
            assert_eq!(nnza, nnzb, "thresh={thresh}");
        }
    }

    #[test]
    fn ties_at_threshold_keep_at_least_k() {
        // duplicated magnitudes: mask keeps >= k (all ties pass)
        let g = [2f32, 2.0, 2.0, 1.0];
        let t = topk_threshold(&g, 2);
        assert_eq!(t, 2.0);
        assert_eq!(g.iter().filter(|v| v.abs() >= t).count(), 3);
    }
}
