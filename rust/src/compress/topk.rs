//! Top-k threshold selection + native mask/stats fallback.
//!
//! The magnitude threshold is found with `select_nth_unstable` — O(d)
//! average, no full sort — in the coordinator; the Pallas kernel (or
//! [`mask_stats_native`], its bit-exact Rust mirror used by tests and the
//! kernel-ablation bench) then applies the mask in one streaming pass.

/// k-th largest magnitude of `g` (the mask keeps `|g_j| >= thresh`).
/// `k = 0` returns +inf (nothing survives); `k >= d` returns 0 (all pass).
pub fn topk_threshold(g: &[f32], k: usize) -> f32 {
    let d = g.len();
    if k == 0 || d == 0 {
        return f32::INFINITY;
    }
    if k >= d {
        return 0.0;
    }
    let mut mags: Vec<f32> = g.iter().map(|v| v.abs()).collect();
    // nth element in descending order = index k-1
    let (_, nth, _) = mags.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
    *nth
}

/// Threshold for keeping a `ratio` fraction (CR) of `g`'s elements.
pub fn threshold_for_ratio(g: &[f32], ratio: f64) -> (usize, f32) {
    let k = ((g.len() as f64 * ratio).ceil() as usize).clamp(1, g.len().max(1));
    (k, topk_threshold(g, k))
}

/// Native mirror of the Pallas `topk_mask_stats` kernel: zero sub-threshold
/// entries in place and return `(|g|², |Topk(g)|², nnz)`.
pub fn mask_stats_native(g: &mut [f32], thresh: f32) -> (f64, f64, usize) {
    let mut norm2 = 0f64;
    let mut knorm2 = 0f64;
    let mut nnz = 0usize;
    for v in g.iter_mut() {
        let x = *v as f64;
        norm2 += x * x;
        if v.abs() >= thresh {
            knorm2 += x * x;
            nnz += 1;
        } else {
            *v = 0.0;
        }
    }
    (norm2, knorm2, nnz)
}

/// Sparse view of a masked gradient: (indices, values) of survivors.
/// What actually crosses the network at 8 bytes/element.
pub fn sparsify(g: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let mut idx = Vec::new();
    let mut val = Vec::new();
    for (i, &v) in g.iter().enumerate() {
        if v != 0.0 {
            idx.push(i as u32);
            val.push(v);
        }
    }
    (idx, val)
}

/// Reassemble a dense gradient from its sparse view.
pub fn densify(d: usize, idx: &[u32], val: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; d];
    for (&i, &v) in idx.iter().zip(val) {
        out[i as usize] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_selects_exactly_k_distinct_magnitudes() {
        let g = [0.1f32, -5.0, 3.0, 0.2, -0.4, 2.0];
        let t = topk_threshold(&g, 3);
        assert_eq!(t, 2.0);
        let kept = g.iter().filter(|v| v.abs() >= t).count();
        assert_eq!(kept, 3);
    }

    #[test]
    fn threshold_edges() {
        let g = [1f32, 2.0, 3.0];
        assert_eq!(topk_threshold(&g, 0), f32::INFINITY);
        assert_eq!(topk_threshold(&g, 3), 0.0);
        assert_eq!(topk_threshold(&[], 1), f32::INFINITY);
    }

    #[test]
    fn ratio_keeps_cr_fraction() {
        // distinct magnitudes 1..=1000 with alternating signs
        let g: Vec<f32> = (0..1000)
            .map(|i| (i + 1) as f32 * if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let (k, t) = threshold_for_ratio(&g, 0.1);
        assert_eq!(k, 100);
        let kept = g.iter().filter(|v| v.abs() >= t).count();
        assert_eq!(kept, 100);
    }

    #[test]
    fn mask_stats_match_definition() {
        let mut g = vec![1f32, -2.0, 0.5, 4.0];
        let (n2, k2, nnz) = mask_stats_native(&mut g, 2.0);
        assert_eq!(n2, 1.0 + 4.0 + 0.25 + 16.0);
        assert_eq!(k2, 4.0 + 16.0);
        assert_eq!(nnz, 2);
        assert_eq!(g, vec![0.0, -2.0, 0.0, 4.0]);
    }

    #[test]
    fn sparsify_roundtrip() {
        let g = vec![0f32, 3.0, 0.0, -1.0, 0.0];
        let (i, v) = sparsify(&g);
        assert_eq!(i, vec![1, 3]);
        assert_eq!(densify(5, &i, &v), g);
    }

    #[test]
    fn ties_at_threshold_keep_at_least_k() {
        // duplicated magnitudes: mask keeps >= k (all ties pass)
        let g = [2f32, 2.0, 2.0, 1.0];
        let t = topk_threshold(&g, 2);
        assert_eq!(t, 2.0);
        assert_eq!(g.iter().filter(|v| v.abs() >= t).count(), 3);
    }
}
