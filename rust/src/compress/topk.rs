//! Top-k threshold selection + native mask/stats fallback.
//!
//! The magnitude threshold is found with a histogram/radix select over
//! the f32 magnitude bit patterns — O(d) worst case, two streaming
//! passes over `g` plus up to three short passes over one exponent
//! bucket — in the coordinator; the Pallas kernel (or
//! [`mask_stats_native`], its bit-exact Rust mirror used by tests and the
//! kernel-ablation bench) then applies the mask in one streaming pass.
//! The pre-radix `select_nth_unstable` path survives as
//! [`topk_threshold_select_nth_with`], the reference both the equality
//! tests and the tracked `topk/select-scratch-reuse` bench diff against.
//!
//! Why the radix answer is *bitwise* the select-nth answer: magnitudes
//! are sign-cleared f32s, and for non-negative IEEE-754 floats the u32
//! bit pattern is monotone in `total_cmp` order (+0.0 < subnormals <
//! normals < +inf < NaN in both). The k-th largest magnitude therefore
//! has the k-th largest bit pattern, and recovering that exact pattern
//! byte-by-byte (MSD first) reproduces `select_nth_unstable_by(k-1,
//! descending total_cmp)` bit for bit — same threshold, same mask.

/// The sign bit: `v.to_bits() & MAG_MASK == v.abs().to_bits()`.
const MAG_MASK: u32 = 0x7FFF_FFFF;

/// Reusable buffers for threshold selection.
///
/// The radix path histograms `g` in place (no magnitude copy) and only
/// materializes the one exponent bucket holding the answer into `keys`;
/// the reference select-nth path still fills the d-length magnitude
/// copy `buf` — 3.2 MB per device-round at mlp_c10's d = 820 874.
/// Workers own one of these and route through
/// [`threshold_for_ratio_with`], which reuses the same buffers each
/// round; the compressed steady state allocates nothing for selection
/// (pinned by `tests/alloc_steady_state.rs` — `with_capacity` pre-sizes
/// `keys` for the worst-case bucket, all of `g` in one exponent bin).
#[derive(Debug, Clone, Default)]
pub struct SelectScratch {
    buf: Vec<f32>,
    keys: Vec<u32>,
}

impl SelectScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for a gradient dimension.
    pub fn with_capacity(d: usize) -> Self {
        Self { buf: Vec::with_capacity(d), keys: Vec::with_capacity(d) }
    }
}

/// k-th largest magnitude of `g` (the mask keeps `|g_j| >= thresh`).
/// `k = 0` returns +inf (nothing survives); `k >= d` returns 0 (all pass).
pub fn topk_threshold(g: &[f32], k: usize) -> f32 {
    topk_threshold_with(g, k, &mut SelectScratch::new())
}

/// [`topk_threshold`] over a caller-owned scratch — identical result,
/// no allocation once the scratch capacity has reached `g.len()`.
///
/// Radix/histogram select over the magnitude bit patterns, MSD first:
///
///  1. one pass histograms the top magnitude byte of every element into
///     four independent sub-histograms (chunked so the increments of a
///     4-wide block never collide on one counter — the store-to-load
///     chain the scalar loop would serialize on), walks the merged bins
///     high-to-low to find the byte holding the k-th largest pattern;
///  2. one pass collects that bucket's full bit patterns into
///     `scratch.keys`;
///  3. up to three short histogram+compact passes over `keys` pin the
///     remaining bytes (early-out when one candidate is left).
///
/// Bitwise identical to the select-nth reference (see module docs).
pub fn topk_threshold_with(g: &[f32], k: usize, scratch: &mut SelectScratch) -> f32 {
    let d = g.len();
    if k == 0 || d == 0 {
        return f32::INFINITY;
    }
    if k >= d {
        return 0.0;
    }

    // -- pass 1: top-byte histogram over g (no copy) --------------------
    let mut sub = [[0usize; 256]; 4];
    let mut chunks = g.chunks_exact(4);
    for c in &mut chunks {
        sub[0][((c[0].to_bits() & MAG_MASK) >> 24) as usize] += 1;
        sub[1][((c[1].to_bits() & MAG_MASK) >> 24) as usize] += 1;
        sub[2][((c[2].to_bits() & MAG_MASK) >> 24) as usize] += 1;
        sub[3][((c[3].to_bits() & MAG_MASK) >> 24) as usize] += 1;
    }
    for v in chunks.remainder() {
        sub[0][((v.to_bits() & MAG_MASK) >> 24) as usize] += 1;
    }
    let mut hist = [0usize; 256];
    for s in &sub {
        for (h, c) in hist.iter_mut().zip(s) {
            *h += c;
        }
    }

    // walk bins high-to-low: the answer's top byte is the first bin
    // where the cumulative count from above reaches k. `remaining` ends
    // as the rank of the answer *within* that bin (1-based, largest
    // first). Total count is d >= k, so the walk always terminates.
    let mut remaining = k;
    let mut byte = 255usize;
    loop {
        if hist[byte] >= remaining {
            break;
        }
        remaining -= hist[byte];
        byte -= 1;
    }

    // -- pass 2: collect the winning bucket's bit patterns --------------
    let top = byte as u32;
    scratch.keys.clear();
    scratch.keys.extend(
        g.iter().map(|v| v.to_bits() & MAG_MASK).filter(|&bits| bits >> 24 == top),
    );

    // -- passes 3..5: pin the remaining bytes over the bucket ------------
    for shift in [16u32, 8, 0] {
        if scratch.keys.len() == 1 {
            break;
        }
        let mut h = [0usize; 256];
        for &bits in scratch.keys.iter() {
            h[((bits >> shift) & 0xFF) as usize] += 1;
        }
        let mut byte = 255usize;
        loop {
            if h[byte] >= remaining {
                break;
            }
            remaining -= h[byte];
            byte -= 1;
        }
        let want = byte as u32;
        let mut w = 0usize;
        for i in 0..scratch.keys.len() {
            let bits = scratch.keys[i];
            if (bits >> shift) & 0xFF == want {
                scratch.keys[w] = bits;
                w += 1;
            }
        }
        scratch.keys.truncate(w);
    }
    // every byte is pinned (or a single candidate survived): all
    // remaining keys are the answer's exact bit pattern
    f32::from_bits(scratch.keys[0])
}

/// Pre-radix reference: `select_nth_unstable` over a d-length magnitude
/// copy. Kept as the ground truth the radix path must match bitwise
/// (pinned in tests and `tests/proptests.rs`) and as the tracked
/// `topk/select-scratch-reuse` bench case the `topk/select-radix`
/// speedup is measured against.
pub fn topk_threshold_select_nth_with(
    g: &[f32],
    k: usize,
    scratch: &mut SelectScratch,
) -> f32 {
    let d = g.len();
    if k == 0 || d == 0 {
        return f32::INFINITY;
    }
    if k >= d {
        return 0.0;
    }
    scratch.buf.clear();
    scratch.buf.extend(g.iter().map(|v| v.abs()));
    // nth element in descending order = index k-1
    let (_, nth, _) = scratch.buf.select_nth_unstable_by(k - 1, |a, b| b.total_cmp(a));
    *nth
}

/// Threshold for keeping a `ratio` fraction (CR) of `g`'s elements.
pub fn threshold_for_ratio(g: &[f32], ratio: f64) -> (usize, f32) {
    threshold_for_ratio_with(g, ratio, &mut SelectScratch::new())
}

/// [`threshold_for_ratio`] over a caller-owned selection scratch.
pub fn threshold_for_ratio_with(
    g: &[f32],
    ratio: f64,
    scratch: &mut SelectScratch,
) -> (usize, f32) {
    let k = ((g.len() as f64 * ratio).ceil() as usize).clamp(1, g.len().max(1));
    (k, topk_threshold_with(g, k, scratch))
}

/// [`threshold_for_ratio_with`] through the select-nth reference path —
/// the baseline side of the radix speedup measurement.
pub fn threshold_for_ratio_select_nth_with(
    g: &[f32],
    ratio: f64,
    scratch: &mut SelectScratch,
) -> (usize, f32) {
    let k = ((g.len() as f64 * ratio).ceil() as usize).clamp(1, g.len().max(1));
    (k, topk_threshold_select_nth_with(g, k, scratch))
}

/// Native mirror of the Pallas `topk_mask_stats` kernel: zero sub-threshold
/// entries in place and return `(|g|², |Topk(g)|², nnz)`.
pub fn mask_stats_native(g: &mut [f32], thresh: f32) -> (f64, f64, usize) {
    let mut norm2 = 0f64;
    let mut knorm2 = 0f64;
    let mut nnz = 0usize;
    for v in g.iter_mut() {
        let x = *v as f64;
        norm2 += x * x;
        if v.abs() >= thresh {
            knorm2 += x * x;
            nnz += 1;
        } else {
            *v = 0.0;
        }
    }
    (norm2, knorm2, nnz)
}

/// Stats-only pass of [`mask_stats_native`]: same `(|g|², |Topk(g)|²,
/// nnz)` — bit for bit, same accumulation order — without zeroing the
/// input. The sparse fast path runs this first so the survivor count is
/// known before [`super::SparseGrad::fill_from_threshold`] reserves,
/// and keeps `g` intact as the *corrected* gradient the error-feedback
/// residual is taken against.
pub fn mask_stats_only(g: &[f32], thresh: f32) -> (f64, f64, usize) {
    let mut norm2 = 0f64;
    let mut knorm2 = 0f64;
    let mut nnz = 0usize;
    for v in g {
        let x = *v as f64;
        norm2 += x * x;
        if v.abs() >= thresh {
            knorm2 += x * x;
            nnz += 1;
        }
    }
    (norm2, knorm2, nnz)
}

/// Sparse view of a masked gradient: (indices, values) of survivors.
/// What actually crosses the network at 8 bytes/element. `nnz_hint`
/// (known from the mask-stats pass) sizes the output vectors in one
/// reserve instead of growing from empty; a wrong hint only costs the
/// usual doubling growth. Thin wrapper over
/// [`super::SparseGrad::fill_from_masked`] — one implementation of the
/// non-zero scan, two shapes of output.
pub fn sparsify(g: &[f32], nnz_hint: usize) -> (Vec<u32>, Vec<f32>) {
    // with_capacity (exact) rather than a bare reserve (amortized, may
    // round up): the capacity-respecting contract is part of the API
    let mut s = super::SparseGrad::with_capacity(nnz_hint);
    s.fill_from_masked(g, nnz_hint);
    (s.idx, s.val)
}

/// Reassemble a dense gradient from its sparse view.
pub fn densify(d: usize, idx: &[u32], val: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; d];
    for (&i, &v) in idx.iter().zip(val) {
        out[i as usize] = v;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_selects_exactly_k_distinct_magnitudes() {
        let g = [0.1f32, -5.0, 3.0, 0.2, -0.4, 2.0];
        let t = topk_threshold(&g, 3);
        assert_eq!(t, 2.0);
        let kept = g.iter().filter(|v| v.abs() >= t).count();
        assert_eq!(kept, 3);
    }

    #[test]
    fn threshold_edges() {
        let g = [1f32, 2.0, 3.0];
        assert_eq!(topk_threshold(&g, 0), f32::INFINITY);
        assert_eq!(topk_threshold(&g, 3), 0.0);
        assert_eq!(topk_threshold(&[], 1), f32::INFINITY);
    }

    #[test]
    fn ratio_keeps_cr_fraction() {
        // distinct magnitudes 1..=1000 with alternating signs
        let g: Vec<f32> = (0..1000)
            .map(|i| (i + 1) as f32 * if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let (k, t) = threshold_for_ratio(&g, 0.1);
        assert_eq!(k, 100);
        let kept = g.iter().filter(|v| v.abs() >= t).count();
        assert_eq!(kept, 100);
    }

    #[test]
    fn mask_stats_match_definition() {
        let mut g = vec![1f32, -2.0, 0.5, 4.0];
        let (n2, k2, nnz) = mask_stats_native(&mut g, 2.0);
        assert_eq!(n2, 1.0 + 4.0 + 0.25 + 16.0);
        assert_eq!(k2, 4.0 + 16.0);
        assert_eq!(nnz, 2);
        assert_eq!(g, vec![0.0, -2.0, 0.0, 4.0]);
    }

    #[test]
    fn sparsify_roundtrip() {
        let g = vec![0f32, 3.0, 0.0, -1.0, 0.0];
        let (i, v) = sparsify(&g, 2);
        assert_eq!(i, vec![1, 3]);
        assert_eq!(densify(5, &i, &v), g);
    }

    #[test]
    fn sparsify_respects_the_capacity_hint() {
        let g = vec![0f32, 3.0, 0.0, -1.0, 0.0, 2.5];
        // the hint pre-sizes the vectors (with_capacity guarantees *at
        // least* n — exactness is a std implementation detail we don't
        // pin); an exact hint must not trigger any growth reallocation,
        // which we observe as capacity staying at its initial value
        let (i, v) = sparsify(&g, 3);
        assert_eq!(i.len(), 3);
        let hinted_cap = crate::compress::SparseGrad::with_capacity(3).idx.capacity();
        assert_eq!(i.capacity(), hinted_cap);
        assert_eq!(v.capacity(), hinted_cap);
        // an under-hint still produces the right answer (vec growth)
        let (i2, v2) = sparsify(&g, 0);
        assert_eq!(i2, i);
        assert_eq!(v2, v);
    }

    #[test]
    fn scratch_reuse_matches_the_allocating_path() {
        let g: Vec<f32> = (0..500)
            .map(|i| ((i * 37 % 101) as f32 - 50.0) * 0.3)
            .collect();
        let mut scratch = SelectScratch::with_capacity(g.len());
        for k in [1usize, 3, 50, 499, 500, 600] {
            assert_eq!(
                topk_threshold(&g, k).to_bits(),
                topk_threshold_with(&g, k, &mut scratch).to_bits(),
                "k={k}"
            );
        }
        for ratio in [0.001, 0.1, 0.5, 1.0] {
            assert_eq!(
                threshold_for_ratio(&g, ratio),
                threshold_for_ratio_with(&g, ratio, &mut scratch),
                "ratio={ratio}"
            );
        }
        // warm scratch never reallocates (radix keys + reference buf)
        topk_threshold_select_nth_with(&g, 10, &mut scratch);
        let (cap, ptr) = (scratch.buf.capacity(), scratch.buf.as_ptr());
        let (kcap, kptr) = (scratch.keys.capacity(), scratch.keys.as_ptr());
        topk_threshold_with(&g, 10, &mut scratch);
        topk_threshold_select_nth_with(&g, 10, &mut scratch);
        assert_eq!(scratch.buf.capacity(), cap);
        assert_eq!(scratch.buf.as_ptr(), ptr);
        assert_eq!(scratch.keys.capacity(), kcap);
        assert_eq!(scratch.keys.as_ptr(), kptr);
    }

    /// Deterministic mixed-magnitude vector: normals across many
    /// exponents, duplicate magnitudes, exact ties of opposite sign,
    /// signed zeros and subnormals.
    fn adversarial(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::rng::Pcg64::new(seed, 17);
        (0..d)
            .map(|i| match i % 11 {
                0 => 0.0,
                1 => -0.0,
                2 => f32::from_bits(1 + (i as u32 % 7)), // subnormals
                3 => f32::MIN_POSITIVE / 2.0,
                4 => (rng.normal() as f32).abs(),
                5 => -((i / 11) as f32 % 13.0),
                6 => (i / 11) as f32 % 13.0, // |dup| of arm 5
                _ => rng.normal() as f32 * (10f32).powi((i % 9) as i32 - 4),
            })
            .collect()
    }

    /// Satellite coverage: the radix select reproduces the select-nth
    /// reference *exactly* — same k, bitwise-same threshold, identical
    /// survivor mask — over seeds x d x CR, ties and zero/subnormal
    /// edges included.
    #[test]
    fn radix_matches_select_nth_exactly() {
        for d in [1usize, 100, 820_874] {
            let seeds: &[u64] = if d > 1000 { &[1] } else { &[1, 2, 3] };
            for &seed in seeds {
                let g = adversarial(d, seed);
                let mut radix = SelectScratch::with_capacity(d);
                let mut refsc = SelectScratch::with_capacity(d);
                for ratio in [0.01, 0.1, 1.0] {
                    let (k_r, t_r) = threshold_for_ratio_with(&g, ratio, &mut radix);
                    let (k_s, t_s) =
                        threshold_for_ratio_select_nth_with(&g, ratio, &mut refsc);
                    assert_eq!(k_r, k_s, "d={d} seed={seed} ratio={ratio}");
                    assert_eq!(
                        t_r.to_bits(),
                        t_s.to_bits(),
                        "d={d} seed={seed} ratio={ratio}: radix {t_r} != ref {t_s}"
                    );
                    let mask_r: Vec<bool> = g.iter().map(|v| v.abs() >= t_r).collect();
                    let mask_s: Vec<bool> = g.iter().map(|v| v.abs() >= t_s).collect();
                    assert_eq!(mask_r, mask_s, "d={d} seed={seed} ratio={ratio}");
                }
            }
        }
    }

    #[test]
    fn radix_matches_select_nth_on_duplicate_ties_and_zeros() {
        // every magnitude duplicated, zeros of both signs at the tail
        let g = [3f32, -3.0, 2.0, 2.0, -2.0, 1.0, -1.0, 0.0, -0.0, 0.0];
        let mut a = SelectScratch::new();
        let mut b = SelectScratch::new();
        for k in 1..=g.len() {
            assert_eq!(
                topk_threshold_with(&g, k, &mut a).to_bits(),
                topk_threshold_select_nth_with(&g, k, &mut b).to_bits(),
                "k={k}"
            );
        }
        // all-zero input: threshold is +0.0 for every k, mask keeps all
        let z = [0f32, -0.0, 0.0, -0.0];
        for k in 1..=z.len() {
            assert_eq!(
                topk_threshold_with(&z, k, &mut a).to_bits(),
                topk_threshold_select_nth_with(&z, k, &mut b).to_bits(),
                "zeros k={k}"
            );
        }
        // pure subnormal input exercises the 0x00 exponent bucket
        let s: Vec<f32> = (1u32..=64).map(f32::from_bits).collect();
        for k in [1usize, 7, 33, 64] {
            assert_eq!(
                topk_threshold_with(&s, k, &mut a).to_bits(),
                topk_threshold_select_nth_with(&s, k, &mut b).to_bits(),
                "subnormal k={k}"
            );
        }
    }

    #[test]
    fn radix_reference_edges_agree() {
        let g = [1f32, 2.0, 3.0];
        let mut s = SelectScratch::new();
        assert_eq!(topk_threshold_select_nth_with(&g, 0, &mut s), f32::INFINITY);
        assert_eq!(topk_threshold_select_nth_with(&g, 3, &mut s), 0.0);
        assert_eq!(topk_threshold_select_nth_with(&[], 1, &mut s), f32::INFINITY);
        // d=1, k=1 takes the k >= d early-out on both paths
        assert_eq!(topk_threshold_with(&[5.0f32], 1, &mut s), 0.0);
        assert_eq!(topk_threshold_select_nth_with(&[5.0f32], 1, &mut s), 0.0);
    }

    #[test]
    fn stats_only_matches_the_masking_pass_bitwise() {
        let g: Vec<f32> = (0..300).map(|i| (i as f32 - 150.0) * 0.01).collect();
        for thresh in [0.0f32, 0.4, 1.2, f32::INFINITY] {
            let (n2a, k2a, nnza) = mask_stats_only(&g, thresh);
            let mut masked = g.clone();
            let (n2b, k2b, nnzb) = mask_stats_native(&mut masked, thresh);
            assert_eq!(n2a.to_bits(), n2b.to_bits(), "thresh={thresh}");
            assert_eq!(k2a.to_bits(), k2b.to_bits(), "thresh={thresh}");
            assert_eq!(nnza, nnzb, "thresh={thresh}");
        }
    }

    #[test]
    fn ties_at_threshold_keep_at_least_k() {
        // duplicated magnitudes: mask keeps >= k (all ties pass)
        let g = [2f32, 2.0, 2.0, 1.0];
        let t = topk_threshold(&g, 2);
        assert_eq!(t, 2.0);
        assert_eq!(g.iter().filter(|v| v.abs() >= t).count(), 3);
    }
}
