//! Compression policy objects the coordinator drives.
//!
//! Three schemes spanning the paper's comparison space:
//! * `None`       — dense exchange every round (conventional DDL).
//! * `StaticTopk` — always send Top-k at a fixed CR (prior work:
//!   Aji & Heafield / DGC-style fixed-ratio sparsification).
//! * `AdaptiveTopk` — ScaDLES: Top-k gated by the EWMA error rule.
//!
//! The flow is two-phase so the actual mask/stats pass can run on the L1
//! Pallas kernel: `threshold()` gives the magnitude cut for this gradient;
//! the caller runs the kernel (or the native mirror) to get
//! `(masked, |g|², |Topk(g)|²)`; `decide()` then picks the tensor to
//! exchange and does the accounting.


use super::adaptive::AdaptiveGate;
use super::topk::threshold_for_ratio;
use crate::config::CompressionConfig;

/// Per-round, per-device compression decision.
#[derive(Debug, Clone, Copy)]
pub struct CompressionDecision {
    /// Exchange the masked (sparse) tensor?
    pub compress: bool,
    /// Elements that would survive the mask.
    pub kept: u64,
    /// Dense gradient size.
    pub dense: u64,
    /// Floats this exchange contributes to the communication volume.
    pub floats_sent: u64,
}

/// A device's compression policy.
#[derive(Debug, Clone)]
pub enum CompressionScheme {
    None,
    StaticTopk { ratio: f64 },
    AdaptiveTopk { gate: AdaptiveGate },
}

impl CompressionScheme {
    /// ScaDLES configuration: adaptive when a config is present.
    pub fn from_config(cfg: Option<CompressionConfig>) -> Self {
        match cfg {
            None => CompressionScheme::None,
            Some(c) => CompressionScheme::AdaptiveTopk {
                gate: AdaptiveGate::new(c),
            },
        }
    }

    /// Compression ratio in play, if any.
    pub fn ratio(&self) -> Option<f64> {
        match self {
            CompressionScheme::None => None,
            CompressionScheme::StaticTopk { ratio } => Some(*ratio),
            CompressionScheme::AdaptiveTopk { gate } => Some(gate.config().ratio),
        }
    }

    /// Phase 1: `(k, magnitude threshold)` for this gradient, or `None`
    /// when the scheme never compresses.
    pub fn threshold(&self, g: &[f32]) -> Option<(usize, f32)> {
        self.ratio().map(|r| threshold_for_ratio(g, r))
    }

    /// Phase 2: decide from the kernel's stats. For `None` this is the
    /// dense fallthrough (callers shouldn't normally get here).
    pub fn decide(&mut self, norm2: f64, knorm2: f64, kept: u64, dense: u64) -> CompressionDecision {
        let compress = match self {
            CompressionScheme::None => false,
            CompressionScheme::StaticTopk { .. } => true,
            CompressionScheme::AdaptiveTopk { gate } => gate.decide(norm2, knorm2).compress,
        };
        CompressionDecision {
            compress,
            kept,
            dense,
            floats_sent: if compress { kept } else { dense },
        }
    }

    /// Dense decision for schemes/rounds without compression.
    pub fn dense_decision(dense: u64) -> CompressionDecision {
        CompressionDecision {
            compress: false,
            kept: dense,
            dense,
            floats_sent: dense,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CompressionScheme::None => "none",
            CompressionScheme::StaticTopk { .. } => "static-topk",
            CompressionScheme::AdaptiveTopk { .. } => "adaptive-topk",
        }
    }

    /// Adaptive-gate state for checkpointing (`None` for stateless schemes).
    pub fn gate_state(&self) -> Option<(f64, f64, u64, u64, u64)> {
        match self {
            CompressionScheme::AdaptiveTopk { gate } => Some(gate.raw_state()),
            _ => None,
        }
    }

    /// Restore the adaptive gate (no-op for stateless schemes).
    pub fn restore_gate(&mut self, s: (f64, f64, u64, u64, u64)) {
        if let CompressionScheme::AdaptiveTopk { gate } = self {
            gate.restore(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_compresses() {
        let mut s = CompressionScheme::None;
        assert!(s.threshold(&[1.0, 2.0]).is_none());
        let d = s.decide(10.0, 1.0, 1, 2);
        assert!(!d.compress);
        assert_eq!(d.floats_sent, 2);
    }

    #[test]
    fn static_always_compresses() {
        let mut s = CompressionScheme::StaticTopk { ratio: 0.5 };
        let (k, _) = s.threshold(&[1.0, -4.0, 2.0, 0.5]).unwrap();
        assert_eq!(k, 2);
        let d = s.decide(100.0, 1.0, 2, 4); // terrible error, still compresses
        assert!(d.compress);
        assert_eq!(d.floats_sent, 2);
    }

    #[test]
    fn adaptive_follows_gate() {
        let mut s =
            CompressionScheme::from_config(Some(CompressionConfig::new(0.1, 0.2)));
        let good = s.decide(100.0, 95.0, 10, 100);
        assert!(good.compress);
        let mut s =
            CompressionScheme::from_config(Some(CompressionConfig::new(0.1, 0.2)));
        let bad = s.decide(100.0, 10.0, 10, 100);
        assert!(!bad.compress);
        assert_eq!(bad.floats_sent, 100);
    }
}
