//! CNC ratio + communication-volume accounting (Table V's metrics).


/// Counts compressed/uncompressed synchronization rounds and the
/// cumulative f32 values exchanged.
///
/// "Floats sent" follows the paper's metric: a dense round moves `d`
/// floats per device pair-section (we count one gradient's worth per
/// device, matching the paper's cumulative-volume bookkeeping), a
/// compressed round moves `k = ⌈CR·d⌉`.
#[derive(Debug, Clone, Copy, Default)]
pub struct CncCounter {
    pub compressed_rounds: u64,
    pub dense_rounds: u64,
    pub floats_sent: u64,
}

impl CncCounter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one device's exchange in a round.
    pub fn record(&mut self, compressed: bool, dense_elems: u64, kept_elems: u64) {
        if compressed {
            self.compressed_rounds += 1;
            self.floats_sent += kept_elems;
        } else {
            self.dense_rounds += 1;
            self.floats_sent += dense_elems;
        }
    }

    /// CNC ratio = T_compressed / (T_compressed + T_uncompressed).
    pub fn cnc_ratio(&self) -> f64 {
        let total = self.compressed_rounds + self.dense_rounds;
        if total == 0 {
            0.0
        } else {
            self.compressed_rounds as f64 / total as f64
        }
    }

    /// Rescale the floats-sent figure from the tiny proxy gradient (d
    /// elements) to the paper-scale model (Table V uses ResNet152/VGG19
    /// sizes); CNC and per-round ratios are size-invariant.
    pub fn floats_sent_at_scale(&self, d_actual: u64, d_paper: u64) -> f64 {
        self.floats_sent as f64 * d_paper as f64 / d_actual.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cnc_matches_definition() {
        let mut c = CncCounter::new();
        c.record(true, 1000, 100);
        c.record(true, 1000, 100);
        c.record(false, 1000, 100);
        assert!((c.cnc_ratio() - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.floats_sent, 100 + 100 + 1000);
    }

    #[test]
    fn empty_counter_is_zero() {
        assert_eq!(CncCounter::new().cnc_ratio(), 0.0);
    }

    #[test]
    fn paper_scale_projection() {
        let mut c = CncCounter::new();
        c.record(false, 1000, 0);
        // 1000 floats on a 1e3-param proxy → 6.02e7 on ResNet152
        let scaled = c.floats_sent_at_scale(1000, 60_200_000);
        assert!((scaled - 6.02e7).abs() < 1.0);
    }
}
