//! The adaptive send rule: EWMA-tracked relative compression error vs δ.
//!
//! Paper §IV:  `send(Topk(g))  if  ||g|² − |Topk(g)|²| / |g|² ≤ δ  else
//! send(g)`, with the error tracked as an exponentially weighted moving
//! average so single noisy iterations don't flap the decision. Early in
//! training gradients are large and dense (critical region — error high ⇒
//! dense sends); as training settles the top-k energy share rises and
//! compression switches on — reproducing Table V's CNC behaviour.


use crate::config::CompressionConfig;
use crate::metrics::Ewma;

/// Gate deciding compressed-vs-dense each round per device.
#[derive(Debug, Clone)]
pub struct AdaptiveGate {
    cfg: CompressionConfig,
    err_ewma: Ewma,
    decisions: u64,
    compressed: u64,
}

/// One gating decision with its inputs (logged for Table V debugging).
#[derive(Debug, Clone, Copy)]
pub struct GateDecision {
    pub rel_err: f64,
    pub ewma_err: f64,
    pub compress: bool,
}

impl AdaptiveGate {
    pub fn new(cfg: CompressionConfig) -> Self {
        Self {
            cfg,
            err_ewma: Ewma::new(cfg.ewma_alpha),
            decisions: 0,
            compressed: 0,
        }
    }

    pub fn config(&self) -> &CompressionConfig {
        &self.cfg
    }

    /// Decide from the kernel's energy statistics.
    ///
    /// `norm2 = |g|²`, `knorm2 = |Topk(g)|²` (both from the Pallas kernel
    /// or its native mirror).
    pub fn decide(&mut self, norm2: f64, knorm2: f64) -> GateDecision {
        let rel_err = if norm2 <= 0.0 {
            0.0 // zero gradient: compression is lossless
        } else {
            ((norm2 - knorm2).abs() / norm2).clamp(0.0, 1.0)
        };
        let ewma_err = self.err_ewma.update(rel_err);
        let compress = ewma_err <= self.cfg.delta;
        self.decisions += 1;
        if compress {
            self.compressed += 1;
        }
        GateDecision {
            rel_err,
            ewma_err,
            compress,
        }
    }

    /// Raw `(ewma value, ewma weight, ewma updates, decisions, compressed)`
    /// state for checkpointing.
    pub fn raw_state(&self) -> (f64, f64, u64, u64, u64) {
        let (v, w, u) = self.err_ewma.raw_state();
        (v, w, u, self.decisions, self.compressed)
    }

    /// Restore the gate to an exact [`Self::raw_state`] cursor.
    pub fn restore(&mut self, s: (f64, f64, u64, u64, u64)) {
        self.err_ewma.restore(s.0, s.1, s.2);
        self.decisions = s.3;
        self.compressed = s.4;
    }

    /// Fraction of decisions that chose compression so far.
    pub fn compress_fraction(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.compressed as f64 / self.decisions as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(delta: f64) -> AdaptiveGate {
        AdaptiveGate::new(CompressionConfig::new(0.1, delta))
    }

    #[test]
    fn low_error_compresses() {
        let mut g = gate(0.3);
        // top-k captures 90% of energy → rel err 0.1 ≤ 0.3
        let d = g.decide(100.0, 90.0);
        assert!(d.compress);
    }

    #[test]
    fn high_error_sends_dense() {
        let mut g = gate(0.1);
        let d = g.decide(100.0, 50.0);
        assert!(!d.compress);
    }

    #[test]
    fn zero_gradient_is_lossless() {
        let mut g = gate(0.01);
        assert!(g.decide(0.0, 0.0).compress);
    }

    #[test]
    fn ewma_smooths_flapping() {
        let mut g = gate(0.3);
        for _ in 0..20 {
            g.decide(100.0, 95.0); // err 0.05, well under
        }
        // one noisy spike shouldn't immediately flip the decision
        let d = g.decide(100.0, 40.0); // instantaneous err 0.6
        assert!(d.ewma_err < 0.3, "ewma {}", d.ewma_err);
        assert!(d.compress);
    }

    #[test]
    fn error_improves_enables_compression_over_time() {
        // training progression: energy share of top-k rises
        let mut g = gate(0.2);
        let mut first = true;
        let mut switched_at = None;
        for i in 0..50 {
            let share = 0.4 + 0.012 * i as f64; // 0.4 → 1.0
            let d = g.decide(1.0, share.min(1.0));
            if first {
                assert!(!d.compress, "must start dense");
                first = false;
            }
            if d.compress && switched_at.is_none() {
                switched_at = Some(i);
            }
        }
        let s = switched_at.expect("gate never switched to compression");
        assert!(s > 5 && s < 45, "switch round {s}");
        assert!(g.compress_fraction() > 0.1 && g.compress_fraction() < 0.9);
    }
}
