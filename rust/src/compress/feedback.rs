//! Error feedback (residual accumulation) for sparsified gradients.
//!
//! Top-k discards `(1 − CR)·d` coordinates each round; DGC (Lin et al.,
//! cited in paper §III-C) shows convergence is preserved when the dropped
//! mass is *accumulated locally* and re-added to the next round's gradient
//! instead of lost. This is the standard error-feedback (EF-SGD) loop:
//!
//! ```text
//!   corrected = g + residual
//!   sent      = Topk(corrected)
//!   residual  = corrected − sent
//! ```
//!
//! Optional in ScaDLES runs (`CompressionConfig::error_feedback`); the
//! ablation bench compares accuracy with/without it at aggressive CRs.

/// Per-device residual accumulator.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
    /// L2² of the current residual (diagnostic; decays when compression
    /// is healthy, grows when CR is too aggressive).
    pub residual_norm2: f64,
}

impl ErrorFeedback {
    pub fn new(d: usize) -> Self {
        Self {
            residual: vec![0.0; d],
            residual_norm2: 0.0,
        }
    }

    pub fn dim(&self) -> usize {
        self.residual.len()
    }

    /// Add the stored residual into `g` (call before thresholding).
    pub fn correct(&self, g: &mut [f32]) {
        debug_assert_eq!(g.len(), self.residual.len());
        for (v, r) in g.iter_mut().zip(&self.residual) {
            *v += r;
        }
    }

    /// Record what was *not* sent: `residual = corrected − sent`.
    ///
    /// `corrected` is the gradient after [`correct`]; `sent` is the masked
    /// tensor that actually crossed the wire.
    pub fn absorb(&mut self, corrected: &[f32], sent: &[f32]) {
        debug_assert_eq!(corrected.len(), self.residual.len());
        let mut n2 = 0f64;
        for ((r, c), s) in self.residual.iter_mut().zip(corrected).zip(sent) {
            *r = c - s;
            n2 += (*r as f64) * (*r as f64);
        }
        self.residual_norm2 = n2;
    }

    /// Dense round: everything was sent, residual clears.
    pub fn clear(&mut self) {
        self.residual.iter_mut().for_each(|r| *r = 0.0);
        self.residual_norm2 = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{mask_stats_native, threshold_for_ratio};
    use crate::rng::Pcg64;

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn residual_is_exactly_the_dropped_mass() {
        let d = 1000;
        let g = grad(d, 1);
        let mut ef = ErrorFeedback::new(d);
        let mut corrected = g.clone();
        ef.correct(&mut corrected); // residual 0 → no-op
        assert_eq!(corrected, g);
        let (_k, t) = threshold_for_ratio(&corrected, 0.1);
        let mut sent = corrected.clone();
        mask_stats_native(&mut sent, t);
        ef.absorb(&corrected, &sent);
        // residual + sent == corrected
        for i in 0..d {
            let rebuilt = sent[i] + (corrected[i] - sent[i]);
            assert!((rebuilt - corrected[i]).abs() < 1e-7);
        }
        assert!(ef.residual_norm2 > 0.0);
    }

    #[test]
    fn no_signal_is_lost_over_rounds() {
        // sum of all sent tensors + final residual == sum of all gradients
        let d = 500;
        let mut ef = ErrorFeedback::new(d);
        let mut total_g = vec![0f64; d];
        let mut total_sent = vec![0f64; d];
        for round in 0..20 {
            let g = grad(d, 100 + round);
            for (t, v) in total_g.iter_mut().zip(&g) {
                *t += *v as f64;
            }
            let mut corrected = g.clone();
            ef.correct(&mut corrected);
            let (_k, t) = threshold_for_ratio(&corrected, 0.05);
            let mut sent = corrected.clone();
            mask_stats_native(&mut sent, t);
            ef.absorb(&corrected, &sent);
            for (s, v) in total_sent.iter_mut().zip(&sent) {
                *s += *v as f64;
            }
        }
        for i in 0..d {
            let residual_i = total_g[i] - total_sent[i];
            // final residual must equal the accounting difference
            assert!(
                (residual_i - ef.residual[i] as f64).abs() < 1e-3,
                "coord {i}: {residual_i} vs {}",
                ef.residual[i]
            );
        }
    }

    #[test]
    fn clear_resets() {
        let mut ef = ErrorFeedback::new(10);
        ef.absorb(&vec![1.0; 10], &vec![0.0; 10]);
        assert!(ef.residual_norm2 > 0.0);
        ef.clear();
        assert_eq!(ef.residual_norm2, 0.0);
        let mut g = vec![2.0f32; 10];
        ef.correct(&mut g);
        assert!(g.iter().all(|&v| v == 2.0));
    }
}
