//! Error feedback (residual accumulation) for sparsified gradients.
//!
//! Top-k discards `(1 − CR)·d` coordinates each round; DGC (Lin et al.,
//! cited in paper §III-C) shows convergence is preserved when the dropped
//! mass is *accumulated locally* and re-added to the next round's gradient
//! instead of lost. This is the standard error-feedback (EF-SGD) loop:
//!
//! ```text
//!   corrected = g + residual
//!   sent      = Topk(corrected)
//!   residual  = corrected − sent
//! ```
//!
//! Optional in ScaDLES runs (`CompressionConfig::error_feedback`); the
//! ablation bench compares accuracy with/without it at aggressive CRs.

/// Per-device residual accumulator.
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    residual: Vec<f32>,
    /// L2² of the current residual (diagnostic; decays when compression
    /// is healthy, grows when CR is too aggressive).
    pub residual_norm2: f64,
}

impl ErrorFeedback {
    pub fn new(d: usize) -> Self {
        Self {
            residual: vec![0.0; d],
            residual_norm2: 0.0,
        }
    }

    pub fn dim(&self) -> usize {
        self.residual.len()
    }

    /// Add the stored residual into `g` (call before thresholding).
    pub fn correct(&self, g: &mut [f32]) {
        debug_assert_eq!(g.len(), self.residual.len());
        for (v, r) in g.iter_mut().zip(&self.residual) {
            *v += r;
        }
    }

    /// Record what was *not* sent: `residual = corrected − sent`.
    ///
    /// `corrected` is the gradient after [`correct`]; `sent` is the masked
    /// tensor that actually crossed the wire.
    pub fn absorb(&mut self, corrected: &[f32], sent: &[f32]) {
        debug_assert_eq!(corrected.len(), self.residual.len());
        let mut n2 = 0f64;
        for ((r, c), s) in self.residual.iter_mut().zip(corrected).zip(sent) {
            *r = c - s;
            n2 += (*r as f64) * (*r as f64);
        }
        self.residual_norm2 = n2;
    }

    /// Sparse-path [`Self::absorb`]: the sent tensor is the Top-k
    /// survivor set, so the residual is exactly the corrected gradient
    /// with the kept coordinates zeroed. Instead of a d-length
    /// subtraction against a materialized dense mask, this **swaps** the
    /// corrected buffer in as the new residual (zero copies), zeroes the
    /// `nnz` kept coordinates, and re-derives the norm in one read pass.
    ///
    /// Bitwise identical to the dense path: for kept coordinates the
    /// dense residual is `c − c = +0.0` and this writes a literal `+0.0`;
    /// for dropped ones it is `c − 0.0 = c` and this keeps `c`'s bits;
    /// the `Σ r²` accumulator visits coordinates in the same order, and
    /// adding the kept coordinates' exact `0.0` squares never moves a
    /// non-negative f64 sum. Pinned by `tests/sparse_dense_equivalence.rs`.
    ///
    /// On return `corrected` holds the *previous* residual — garbage to
    /// the caller, to be overwritten when the next round's corrected
    /// gradient is built into the same buffer.
    pub fn absorb_sparse(&mut self, corrected: &mut Vec<f32>, sent: &crate::compress::SparseGrad) {
        debug_assert_eq!(corrected.len(), self.residual.len());
        std::mem::swap(&mut self.residual, corrected);
        for &i in &sent.idx {
            self.residual[i as usize] = 0.0;
        }
        let mut n2 = 0f64;
        for r in &self.residual {
            n2 += (*r as f64) * (*r as f64);
        }
        self.residual_norm2 = n2;
    }

    /// Quantized-wire [`Self::absorb_sparse`]: `sent.val` holds the
    /// *dequantized* survivor values — what actually crossed the wire —
    /// so the residual at a kept coordinate is `corrected − dequant`
    /// rather than an exact `+0.0`: the quantization error joins the
    /// dropped Top-k mass in the residual and is re-injected into the
    /// next round's corrected gradient. Same zero-copy swap as the
    /// sparse path; when the wire is lossless (`dequant == corrected`
    /// at every kept coordinate, e.g. `--wire f32`) the subtraction
    /// yields the same `+0.0` bits `absorb_sparse` writes.
    ///
    /// On return `corrected` holds the *previous* residual — garbage to
    /// the caller, exactly like [`Self::absorb_sparse`].
    pub fn absorb_quantized(
        &mut self,
        corrected: &mut Vec<f32>,
        sent: &crate::compress::SparseGrad,
    ) {
        debug_assert_eq!(corrected.len(), self.residual.len());
        std::mem::swap(&mut self.residual, corrected);
        for (&i, &v) in sent.idx.iter().zip(&sent.val) {
            self.residual[i as usize] -= v;
        }
        let mut n2 = 0f64;
        for r in &self.residual {
            n2 += (*r as f64) * (*r as f64);
        }
        self.residual_norm2 = n2;
    }

    /// A round where this device's contribution was *withheld* entirely
    /// — a semi-synchronous laggard past the commit point (K-sync). The
    /// wire carried nothing, so the whole gradient joins the residual
    /// (`residual += g`) and no mass is lost: the next committed round's
    /// corrected gradient re-adds it, exactly like Top-k's dropped
    /// coordinates.
    pub fn absorb_unsent(&mut self, g: &[f32]) {
        debug_assert_eq!(g.len(), self.residual.len());
        let mut n2 = 0f64;
        for (r, v) in self.residual.iter_mut().zip(g) {
            *r += *v;
            n2 += (*r as f64) * (*r as f64);
        }
        self.residual_norm2 = n2;
    }

    /// Dense round: everything was sent, residual clears.
    pub fn clear(&mut self) {
        self.residual.iter_mut().for_each(|r| *r = 0.0);
        self.residual_norm2 = 0.0;
    }

    /// The raw residual vector (checkpointing).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Restore the residual to exact saved bits; `residual_norm2` is set
    /// by the caller (it is a `pub` field) so the restored diagnostic is
    /// bitwise what the uninterrupted run carried.
    pub fn restore_residual(&mut self, r: &[f32]) {
        assert_eq!(r.len(), self.residual.len(), "residual dim mismatch");
        self.residual.copy_from_slice(r);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{mask_stats_native, threshold_for_ratio};
    use crate::rng::Pcg64;

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn residual_is_exactly_the_dropped_mass() {
        let d = 1000;
        let g = grad(d, 1);
        let mut ef = ErrorFeedback::new(d);
        let mut corrected = g.clone();
        ef.correct(&mut corrected); // residual 0 → no-op
        assert_eq!(corrected, g);
        let (_k, t) = threshold_for_ratio(&corrected, 0.1);
        let mut sent = corrected.clone();
        mask_stats_native(&mut sent, t);
        ef.absorb(&corrected, &sent);
        // residual + sent == corrected
        for i in 0..d {
            let rebuilt = sent[i] + (corrected[i] - sent[i]);
            assert!((rebuilt - corrected[i]).abs() < 1e-7);
        }
        assert!(ef.residual_norm2 > 0.0);
    }

    #[test]
    fn no_signal_is_lost_over_rounds() {
        // sum of all sent tensors + final residual == sum of all gradients
        let d = 500;
        let mut ef = ErrorFeedback::new(d);
        let mut total_g = vec![0f64; d];
        let mut total_sent = vec![0f64; d];
        for round in 0..20 {
            let g = grad(d, 100 + round);
            for (t, v) in total_g.iter_mut().zip(&g) {
                *t += *v as f64;
            }
            let mut corrected = g.clone();
            ef.correct(&mut corrected);
            let (_k, t) = threshold_for_ratio(&corrected, 0.05);
            let mut sent = corrected.clone();
            mask_stats_native(&mut sent, t);
            ef.absorb(&corrected, &sent);
            for (s, v) in total_sent.iter_mut().zip(&sent) {
                *s += *v as f64;
            }
        }
        for i in 0..d {
            let residual_i = total_g[i] - total_sent[i];
            // final residual must equal the accounting difference
            assert!(
                (residual_i - ef.residual[i] as f64).abs() < 1e-3,
                "coord {i}: {residual_i} vs {}",
                ef.residual[i]
            );
        }
    }

    #[test]
    fn sparse_absorb_is_bitwise_equal_to_dense_absorb() {
        use crate::compress::{mask_stats_only, SparseGrad};
        let d = 800;
        for (seed, cr) in [(1u64, 0.1), (2, 0.01), (3, 1.0)] {
            let mut dense_ef = ErrorFeedback::new(d);
            let mut sparse_ef = ErrorFeedback::new(d);
            let mut sparse = SparseGrad::new();
            let mut corrected_s = vec![0f32; d];
            for round in 0..8 {
                let g = grad(d, seed * 1000 + round);
                // dense reference path
                let mut corrected_d = g.clone();
                dense_ef.correct(&mut corrected_d);
                let (_k, t) = threshold_for_ratio(&corrected_d, cr);
                let mut sent = corrected_d.clone();
                mask_stats_native(&mut sent, t);
                dense_ef.absorb(&corrected_d, &sent);
                // sparse path over reused buffers
                corrected_s.copy_from_slice(&g);
                sparse_ef.correct(&mut corrected_s);
                let (_n2, _k2, nnz) = mask_stats_only(&corrected_s, t);
                sparse.fill_from_threshold(&corrected_s, t, nnz);
                sparse_ef.absorb_sparse(&mut corrected_s, &sparse);
                assert_eq!(
                    dense_ef.residual_norm2.to_bits(),
                    sparse_ef.residual_norm2.to_bits(),
                    "seed={seed} cr={cr} round={round}: norm"
                );
                for i in 0..d {
                    assert_eq!(
                        dense_ef.residual[i].to_bits(),
                        sparse_ef.residual[i].to_bits(),
                        "seed={seed} cr={cr} round={round}: coord {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn quantized_absorb_conserves_mass_exactly() {
        // the EF invariant under a lossy wire: residual[i] is bitwise
        // `corrected[i] − dequant[i]` at kept coordinates and bitwise
        // `corrected[i]` at dropped ones — no mass invents or vanishes
        use crate::compress::{mask_stats_only, QuantizedGrad, SparseGrad};
        let d = 600;
        let mut ef = ErrorFeedback::new(d);
        let mut sparse = SparseGrad::new();
        let mut quant = QuantizedGrad::default();
        let mut qrng = Pcg64::new(99, 7);
        let mut corrected = vec![0f32; d];
        for (round, bits) in [(0u64, 8u32), (1, 4), (2, 8), (3, 4)] {
            let g = grad(d, 500 + round);
            corrected.copy_from_slice(&g);
            ef.correct(&mut corrected);
            let snapshot = corrected.clone();
            let (_k, t) = threshold_for_ratio(&corrected, 0.1);
            let (_n2, _k2, nnz) = mask_stats_only(&corrected, t);
            sparse.fill_from_threshold(&corrected, t, nnz);
            quant.encode(&sparse, bits, &mut qrng);
            quant.decode_into(&mut sparse.val);
            ef.absorb_quantized(&mut corrected, &sparse);
            let mut kept = vec![false; d];
            for (&i, &v) in sparse.idx.iter().zip(&sparse.val) {
                kept[i as usize] = true;
                let expect = snapshot[i as usize] - v;
                assert_eq!(
                    ef.residual[i as usize].to_bits(),
                    expect.to_bits(),
                    "round={round} kept coord {i}"
                );
            }
            for i in 0..d {
                if !kept[i] {
                    assert_eq!(
                        ef.residual[i].to_bits(),
                        snapshot[i].to_bits(),
                        "round={round} dropped coord {i}"
                    );
                }
            }
            let expect_n2: f64 =
                ef.residual.iter().map(|r| (*r as f64) * (*r as f64)).sum();
            assert_eq!(ef.residual_norm2.to_bits(), expect_n2.to_bits());
        }
    }

    #[test]
    fn quantized_absorb_of_a_lossless_wire_matches_absorb_sparse() {
        use crate::compress::{mask_stats_only, SparseGrad};
        let d = 400;
        let g = grad(d, 77);
        let (_k, t) = threshold_for_ratio(&g, 0.2);
        let (_n2, _k2, nnz) = mask_stats_only(&g, t);
        let mut sparse = SparseGrad::new();
        sparse.fill_from_threshold(&g, t, nnz);
        let mut a = ErrorFeedback::new(d);
        let mut b = ErrorFeedback::new(d);
        let mut ca = g.clone();
        let mut cb = g.clone();
        a.absorb_sparse(&mut ca, &sparse);
        // identical values on the wire → identical residual bits
        b.absorb_quantized(&mut cb, &sparse);
        assert_eq!(a.residual_norm2.to_bits(), b.residual_norm2.to_bits());
        for i in 0..d {
            assert_eq!(a.residual[i].to_bits(), b.residual[i].to_bits(), "coord {i}");
        }
    }

    #[test]
    fn absorb_unsent_preserves_all_mass() {
        // a withheld round is equivalent to sending nothing: the whole
        // corrected gradient (g + old residual) becomes the residual
        let d = 200;
        let mut ef = ErrorFeedback::new(d);
        let g0 = grad(d, 5);
        ef.absorb_unsent(&g0);
        for i in 0..d {
            assert_eq!(ef.residual[i].to_bits(), g0[i].to_bits());
        }
        let g1 = grad(d, 6);
        ef.absorb_unsent(&g1);
        for i in 0..d {
            assert_eq!(ef.residual[i].to_bits(), (g0[i] + g1[i]).to_bits());
        }
        let expect: f64 = ef.residual.iter().map(|r| (*r as f64) * (*r as f64)).sum();
        assert_eq!(ef.residual_norm2.to_bits(), expect.to_bits());
        // a later correct() re-injects everything
        let mut corrected = vec![0f32; d];
        ef.correct(&mut corrected);
        for i in 0..d {
            assert_eq!(corrected[i].to_bits(), (g0[i] + g1[i]).to_bits());
        }
    }

    #[test]
    fn clear_resets() {
        let mut ef = ErrorFeedback::new(10);
        ef.absorb(&vec![1.0; 10], &vec![0.0; 10]);
        assert!(ef.residual_norm2 > 0.0);
        ef.clear();
        assert_eq!(ef.residual_norm2, 0.0);
        let mut g = vec![2.0f32; 10];
        ef.correct(&mut g);
        assert!(g.iter().all(|&v| v == 2.0));
    }
}
