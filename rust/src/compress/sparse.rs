//! Sparse gradient views: the `(index, value)` pairs that survive a
//! Top-k mask.
//!
//! ScaDLES's communication argument (paper §III-C, Table V) is that
//! Top-k at CR=0.1 moves ~10× less data; [`SparseGrad`] makes the
//! *simulator* pay the same reduced cost. The mask phase produces the
//! coordinate form directly from the corrected gradient — the dense
//! masked tensor is never materialized on the native path — and the
//! coordinator aggregates it in O(nnz) scatters
//! ([`crate::coordinator::aggregate::aggregate_sparse_native`]).
//!
//! Buffers are owned per device and reused round over round: `fill_*`
//! reserves from the exact nnz reported by
//! [`super::topk::mask_stats_only`], so after the first few rounds the
//! capacity has converged and the compressed steady state allocates
//! nothing (pinned by `tests/alloc_steady_state.rs`).
//!
//! Indices are ascending by construction (a single left-to-right scan),
//! which is what makes sparse aggregation bitwise-identical to the
//! dense mirror: per coordinate, contributions still arrive in device
//! order, and coordinates are visited in memory order.

/// A masked gradient in coordinate form: `val[j]` lives at dense index
/// `idx[j]`. Everything not listed is an exact `0.0`.
///
/// `u32` indices cap the dense dimension at 2³²−1 — far above any model
/// in the repo (mlp_c10 is d = 820 874) — and halve the wire/index
/// footprint versus `usize`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SparseGrad {
    /// Dense coordinates of the survivors, strictly ascending.
    pub idx: Vec<u32>,
    /// Survivor values, parallel to `idx`.
    pub val: Vec<f32>,
}

impl SparseGrad {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for an expected survivor count (e.g. `ceil(CR · d)`).
    pub fn with_capacity(nnz: usize) -> Self {
        Self {
            idx: Vec::with_capacity(nnz),
            val: Vec::with_capacity(nnz),
        }
    }

    /// Number of stored coordinates.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }

    pub fn clear(&mut self) {
        self.idx.clear();
        self.val.clear();
    }

    /// Rebuild from a *corrected* (unmasked) gradient and the magnitude
    /// threshold: keeps every `|g_j| >= thresh`, exactly the coordinates
    /// [`super::topk::mask_stats_native`] would keep. `nnz_hint` (the
    /// count from [`super::topk::mask_stats_only`]) sizes the reserve so
    /// a warm buffer never reallocates.
    pub fn fill_from_threshold(&mut self, g: &[f32], thresh: f32, nnz_hint: usize) {
        debug_assert!(g.len() <= u32::MAX as usize, "dense dim exceeds u32 index space");
        self.clear();
        self.idx.reserve(nnz_hint);
        self.val.reserve(nnz_hint);
        for (i, &v) in g.iter().enumerate() {
            if v.abs() >= thresh {
                self.idx.push(i as u32);
                self.val.push(v);
            }
        }
    }

    /// Rebuild from an already-masked dense tensor: keeps the non-zeros
    /// (the wire format [`super::topk::sparsify`] exposes). Note this is
    /// *not* interchangeable with [`Self::fill_from_threshold`] when the
    /// threshold is exactly `0`: a `±0.0` survivor is dropped here but
    /// kept there, which shifts `nnz` and which coordinates the
    /// error-feedback residual zeroes — the round engine therefore
    /// re-thresholds the kernel's masked output instead of scanning it.
    pub fn fill_from_masked(&mut self, masked: &[f32], nnz_hint: usize) {
        debug_assert!(masked.len() <= u32::MAX as usize, "dense dim exceeds u32 index space");
        self.clear();
        self.idx.reserve(nnz_hint);
        self.val.reserve(nnz_hint);
        for (i, &v) in masked.iter().enumerate() {
            if v != 0.0 {
                self.idx.push(i as u32);
                self.val.push(v);
            }
        }
    }

    /// Scatter into a dense buffer (zeroed first). `out.len()` is the
    /// dense dimension and must cover every stored index.
    pub fn densify_into(&self, out: &mut [f32]) {
        out.iter_mut().for_each(|v| *v = 0.0);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
    }

    /// Allocating convenience for tests/benches.
    pub fn densify(&self, d: usize) -> Vec<f32> {
        let mut out = vec![0f32; d];
        self.densify_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::topk::{mask_stats_native, mask_stats_only, threshold_for_ratio};
    use crate::rng::Pcg64;

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn threshold_fill_matches_dense_mask_exactly() {
        let g = grad(2000, 3);
        let (_k, t) = threshold_for_ratio(&g, 0.1);
        let (_n2, _k2, nnz) = mask_stats_only(&g, t);
        let mut s = SparseGrad::new();
        s.fill_from_threshold(&g, t, nnz);
        assert_eq!(s.nnz(), nnz);
        let mut masked = g.clone();
        mask_stats_native(&mut masked, t);
        assert_eq!(s.densify(g.len()), masked);
        // indices strictly ascending by construction
        assert!(s.idx.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn masked_fill_agrees_with_threshold_fill_on_nonzero_survivors() {
        let g = grad(512, 9);
        let (_k, t) = threshold_for_ratio(&g, 0.25);
        let mut masked = g.clone();
        let (_n2, _k2, nnz) = mask_stats_native(&mut masked, t);
        let mut a = SparseGrad::new();
        a.fill_from_threshold(&g, t, nnz);
        let mut b = SparseGrad::new();
        b.fill_from_masked(&masked, nnz);
        assert_eq!(a, b); // normal gradients have no exact-zero survivors
    }

    #[test]
    fn zero_threshold_keeps_explicit_zeros_only_on_the_threshold_path() {
        // CR=1.0 → thresh 0 → the threshold fill stores kept zeros, the
        // masked fill drops them; both densify to the same tensor.
        let g = vec![0f32, 1.0, 0.0, -2.0];
        let mut a = SparseGrad::new();
        a.fill_from_threshold(&g, 0.0, 4);
        assert_eq!(a.nnz(), 4);
        let mut b = SparseGrad::new();
        b.fill_from_masked(&g, 4);
        assert_eq!(b.nnz(), 2);
        assert_eq!(a.densify(4), g);
        assert_eq!(b.densify(4), g);
    }

    #[test]
    fn warm_buffer_does_not_grow_capacity() {
        let g = grad(1000, 5);
        let (_k, t) = threshold_for_ratio(&g, 0.1);
        let (_n2, _k2, nnz) = mask_stats_only(&g, t);
        let mut s = SparseGrad::new();
        s.fill_from_threshold(&g, t, nnz);
        let (cap_i, cap_v) = (s.idx.capacity(), s.val.capacity());
        let (ptr_i, ptr_v) = (s.idx.as_ptr(), s.val.as_ptr());
        for _ in 0..5 {
            s.fill_from_threshold(&g, t, nnz);
        }
        assert_eq!(s.idx.capacity(), cap_i);
        assert_eq!(s.val.capacity(), cap_v);
        assert_eq!(s.idx.as_ptr(), ptr_i);
        assert_eq!(s.val.as_ptr(), ptr_v);
    }

    #[test]
    fn empty_and_infinite_threshold() {
        let mut s = SparseGrad::with_capacity(8);
        s.fill_from_threshold(&[], 0.0, 0);
        assert!(s.is_empty());
        s.fill_from_threshold(&[1.0, -2.0], f32::INFINITY, 0);
        assert!(s.is_empty());
        assert_eq!(s.densify(2), vec![0.0, 0.0]);
    }
}
