//! Quantized `SparseGrad` wire format (`--wire {f32,q8,q4}`).
//!
//! The sparse fast path (PR 4) cut the simulated wire to the Top-k
//! survivors, but each survivor still crossed as a full `u32` index +
//! `f32` value pair. This module is the bits-per-coordinate half of the
//! bandwidth story (paper §III-C; QSGD, Alistarh et al. 2017): survivor
//! values are stochastically quantized to 8 or 4 bits against a
//! per-row scale — the same stochastic-uniform rule as
//! [`super::qsgd`], so the estimate stays unbiased — and the strictly
//! ascending survivor indices are delta-encoded as LEB128 varints.
//!
//! Nothing is byte-serialized in the simulator: [`QuantizedGrad`]
//! holds the levels, the decode produces the lossy values the
//! aggregation actually consumes (so convergence pays the real
//! quantization error, folded into [`super::ErrorFeedback`] exactly
//! like dropped Top-k mass), and [`QuantizedGrad::encoded_bits`]
//! reports the *exact* wire size the network model prices
//! ([`crate::simulate::NetworkModel::quantized_sync_time`]). The exact
//! accounting helpers are shared with the QSGD baseline in
//! [`super::baselines`] so ablation tables and wire pricing agree.

use crate::compress::SparseGrad;
use crate::rng::Pcg64;

/// Bits of the per-row f32 scale scalar.
pub const SCALE_BITS: u64 = 32;

/// Exact LEB128 size of one varint: 8 bits per started 7-bit group.
pub fn varint_bits(v: u64) -> u64 {
    let significant = 64 - v.max(1).leading_zeros() as u64;
    significant.div_ceil(7) * 8
}

/// Exact bit count of the delta-encoded varint index stream: the first
/// index absolute, every later one as the (strictly positive, indices
/// ascending) difference to its predecessor.
pub fn delta_index_bits(idx: &[u32]) -> u64 {
    let mut bits = 0u64;
    let mut prev = 0u32;
    for (j, &i) in idx.iter().enumerate() {
        let delta = if j == 0 { i as u64 } else { (i - prev) as u64 };
        bits += varint_bits(delta);
        prev = i;
    }
    bits
}

/// Exact size in bits of a stochastically quantized value stream: one
/// f32 scale + (sign + `value_bits` level) per coordinate. Shared with
/// the QSGD baseline's [`super::Encoded::encoded_bits`].
pub fn quantized_value_bits(n: usize, value_bits: u32) -> u64 {
    SCALE_BITS + n as u64 * (1 + value_bits as u64)
}

/// A sparse row's values quantized for the wire. The indices stay on
/// the companion [`SparseGrad`]; this holds the signed levels and the
/// per-row scale needed to decode them.
#[derive(Debug, Clone, Default)]
pub struct QuantizedGrad {
    /// Level bits per value: 8 (255 levels) or 4 (15 levels).
    pub value_bits: u32,
    /// Per-row scale: the survivor set's max |value|.
    pub scale: f32,
    /// Signed quantization levels, `|q| <= levels(value_bits)`.
    pub qvals: Vec<i16>,
}

impl QuantizedGrad {
    /// Levels representable at `value_bits`: `2^bits − 1`.
    pub fn levels(value_bits: u32) -> u32 {
        (1u32 << value_bits) - 1
    }

    /// Stochastic-uniform encode of `sparse.val` — the [`super::qsgd`]
    /// rule against the row's max-|v| scale: `ξ = ⌊r⌋ + Bernoulli(r −
    /// ⌊r⌋)` with `r = |v|/scale · levels`, so `E[decode] = v`
    /// (unbiased). One RNG draw per survivor, unconditionally, which
    /// keeps the draw count a pure function of nnz (checkpoint/restore
    /// replays bitwise). A zero scale (all-zero survivor row) encodes
    /// to all-zero levels without touching the RNG.
    pub fn encode(&mut self, sparse: &SparseGrad, value_bits: u32, rng: &mut Pcg64) {
        debug_assert!(value_bits == 4 || value_bits == 8);
        self.value_bits = value_bits;
        self.qvals.clear();
        self.scale = sparse.val.iter().fold(0f32, |m, v| m.max(v.abs()));
        if self.scale == 0.0 {
            self.qvals.resize(sparse.val.len(), 0);
            return;
        }
        let levels = Self::levels(value_bits) as f32;
        self.qvals.extend(sparse.val.iter().map(|&v| {
            let ratio = (v.abs() / self.scale) * levels; // in [0, levels]
            let floor = ratio.floor();
            let p = ratio - floor; // probability of rounding up
            let q = floor + if (rng.f64() as f32) < p { 1.0 } else { 0.0 };
            if v.is_sign_negative() {
                -(q as i16)
            } else {
                q as i16
            }
        }));
    }

    /// Dequantize over `val` in place (`val.len() == qvals.len()`):
    /// `v = scale · q / levels`. This lossy tensor is what the
    /// aggregation consumes — the simulator trains on exactly what
    /// crossed the wire.
    pub fn decode_into(&self, val: &mut [f32]) {
        debug_assert_eq!(val.len(), self.qvals.len());
        let levels = Self::levels(self.value_bits) as f32;
        for (v, &q) in val.iter_mut().zip(&self.qvals) {
            *v = self.scale * q as f32 / levels;
        }
    }

    /// Exact wire size in bits of this row: scale + sign/level stream +
    /// delta-varint indices (`idx` is the companion survivor index
    /// array).
    pub fn encoded_bits(&self, idx: &[u32]) -> u64 {
        debug_assert_eq!(idx.len(), self.qvals.len());
        quantized_value_bits(self.qvals.len(), self.value_bits) + delta_index_bits(idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sparse_row(vals: &[f32]) -> SparseGrad {
        let mut s = SparseGrad::new();
        for (j, &v) in vals.iter().enumerate() {
            s.idx.push((j * 7 + 3) as u32);
            s.val.push(v);
        }
        s
    }

    #[test]
    fn varint_bits_match_leb128_group_counts() {
        assert_eq!(varint_bits(0), 8);
        assert_eq!(varint_bits(1), 8);
        assert_eq!(varint_bits(127), 8);
        assert_eq!(varint_bits(128), 16);
        assert_eq!(varint_bits(16_383), 16);
        assert_eq!(varint_bits(16_384), 24);
        assert_eq!(varint_bits(u32::MAX as u64), 40);
    }

    #[test]
    fn delta_bits_reward_dense_survivor_runs() {
        // consecutive indices: first absolute + 1-byte deltas
        let tight: Vec<u32> = (1000..1100).collect();
        assert_eq!(delta_index_bits(&tight), 16 + 99 * 8);
        // the same count spread wide costs more
        let wide: Vec<u32> = (0..100).map(|i| i * 100_000).collect();
        assert!(delta_index_bits(&wide) > delta_index_bits(&tight));
        assert_eq!(delta_index_bits(&[]), 0);
    }

    #[test]
    fn roundtrip_error_is_bounded_by_one_level() {
        let mut rng = Pcg64::new(11, 0);
        for bits in [8u32, 4] {
            let s = sparse_row(&[0.5, -1.25, 3.0, -0.001, 2.999]);
            let mut q = QuantizedGrad::default();
            q.encode(&s, bits, &mut rng);
            assert_eq!(q.scale, 3.0);
            let mut out = s.val.clone();
            q.decode_into(&mut out);
            let step = q.scale / QuantizedGrad::levels(bits) as f32;
            for (a, b) in s.val.iter().zip(&out) {
                assert!((a - b).abs() <= step * 1.0001, "bits={bits}: {a} vs {b}");
                assert!(
                    b.abs() == 0.0 || a.is_sign_negative() == b.is_sign_negative(),
                    "sign flipped: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn max_magnitude_survivor_is_exact() {
        // |v| == scale quantizes to the top level deterministically
        let s = sparse_row(&[2.0, -2.0, 1.0]);
        for bits in [8u32, 4] {
            let mut rng = Pcg64::new(3, 0);
            let mut q = QuantizedGrad::default();
            q.encode(&s, bits, &mut rng);
            let levels = QuantizedGrad::levels(bits) as i16;
            assert_eq!(q.qvals[0], levels);
            assert_eq!(q.qvals[1], -levels);
            let mut out = s.val.clone();
            q.decode_into(&mut out);
            assert_eq!(out[0], 2.0);
            assert_eq!(out[1], -2.0);
        }
    }

    #[test]
    fn empty_and_zero_rows() {
        let mut rng = Pcg64::new(5, 0);
        let mut q = QuantizedGrad::default();
        q.encode(&SparseGrad::new(), 8, &mut rng);
        assert!(q.qvals.is_empty());
        assert_eq!(q.encoded_bits(&[]), SCALE_BITS);
        // all-zero survivors: zero scale, no RNG draws, decodes to zeros
        let z = sparse_row(&[0.0, -0.0, 0.0]);
        let before = rng.f64();
        let mut rng2 = Pcg64::new(5, 0);
        let _ = rng2.f64();
        q.encode(&z, 4, &mut rng2);
        let after = rng2.f64();
        let mut probe = Pcg64::new(5, 0);
        let _ = probe.f64();
        assert_eq!(after, probe.f64(), "zero row must not consume draws");
        let _ = before;
        let mut out = z.val.clone();
        q.decode_into(&mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn encoded_bits_are_exact() {
        let s = sparse_row(&[1.0, -0.5, 0.25, 2.0]);
        let mut rng = Pcg64::new(9, 0);
        let mut q = QuantizedGrad::default();
        q.encode(&s, 8, &mut rng);
        // idx = [3, 10, 17, 24]: 4 one-byte varints; values: 4·(1+8)
        assert_eq!(q.encoded_bits(&s.idx), 32 + 4 * 9 + 4 * 8);
        q.encode(&s, 4, &mut rng);
        assert_eq!(q.encoded_bits(&s.idx), 32 + 4 * 5 + 4 * 8);
        // q8 beats the 64-bit f32+u32 pair per survivor by ~3.5x here
        assert!(q.encoded_bits(&s.idx) < 4 * 64);
    }

    #[test]
    fn quantization_is_unbiased() {
        let s = sparse_row(&[0.3, -0.7, 0.11, 0.9999, -0.0003]);
        let mut rng = Pcg64::new(21, 0);
        let trials = 4000;
        let mut mean = vec![0f64; s.val.len()];
        let mut q = QuantizedGrad::default();
        let mut out = vec![0f32; s.val.len()];
        for _ in 0..trials {
            q.encode(&s, 4, &mut rng);
            out.copy_from_slice(&s.val);
            q.decode_into(&mut out);
            for (m, &v) in mean.iter_mut().zip(&out) {
                *m += v as f64 / trials as f64;
            }
        }
        let scale = s.val.iter().fold(0f32, |m, v| m.max(v.abs()));
        let step = (scale / QuantizedGrad::levels(4) as f32) as f64;
        for (m, &v) in mean.iter().zip(&s.val) {
            assert!((m - v as f64).abs() < step * 0.1, "{m} vs {v}");
        }
    }
}
