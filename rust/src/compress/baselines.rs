//! Quantization baselines from the paper's related work (§III-C).
//!
//! ScaDLES's adaptive Top-k is evaluated against the fixed-ratio /
//! fixed-bitwidth families it improves on; these are faithful, testable
//! implementations used by the ablation benches:
//!
//! * [`qsgd`] — QSGD (Alistarh et al. 2017): stochastic uniform
//!   quantization to `s` levels per |g|∞-normalized coordinate. Unbiased:
//!   `E[Q(g)] = g`.
//! * [`terngrad`] — TernGrad (Wen et al. 2017): stochastic ternarization
//!   to `{−1, 0, +1}·s` with `s = max|g|`. Also unbiased.
//! * AMP-style fp16 casting ([`fp16_roundtrip`]) — the 2× "compression"
//!   of mixed-precision training.
//!
//! All operate out-of-place on flat gradients and report their
//! communication volume in *equivalent f32 floats* so Table V-style
//! accounting can compare them with Top-k.

use crate::compress::wire::{quantized_value_bits, SCALE_BITS};
use crate::rng::Pcg64;

/// Result of a lossy gradient encoding.
#[derive(Debug, Clone)]
pub struct Encoded {
    /// Decoded (lossy) gradient, ready for aggregation.
    pub decoded: Vec<f32>,
    /// Wire cost in equivalent f32 floats (bits / 32). Kept for the
    /// historical Table V-style accounting; derived from
    /// [`Self::encoded_bits`] so the two can never disagree.
    pub float_equiv: f64,
    /// *Exact* wire cost in bits — the same accounting the `--wire`
    /// formats use ([`crate::compress::wire`]), so ablation tables and
    /// wire pricing agree.
    pub encoded_bits: u64,
}

impl Encoded {
    fn from_bits(decoded: Vec<f32>, encoded_bits: u64) -> Self {
        Self { decoded, float_equiv: encoded_bits as f64 / 32.0, encoded_bits }
    }
}

/// QSGD with `levels` quantization levels (levels = 2^bits − 1).
///
/// Each coordinate is mapped to `sign(g_i) · ‖g‖₂ · ξ_i / levels` where
/// `ξ_i ∈ {0..levels}` is drawn so the estimate is unbiased.
pub fn qsgd(g: &[f32], levels: u32, rng: &mut Pcg64) -> Encoded {
    assert!(levels >= 1);
    let norm = g.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32;
    if norm == 0.0 {
        // just the norm scalar
        return Encoded::from_bits(vec![0.0; g.len()], SCALE_BITS);
    }
    let mut decoded = Vec::with_capacity(g.len());
    for &v in g {
        let ratio = (v.abs() / norm) * levels as f32; // in [0, levels]
        let floor = ratio.floor();
        let p = ratio - floor; // probability of rounding up
        let q = floor + if (rng.f64() as f32) < p { 1.0 } else { 0.0 };
        decoded.push(v.signum() * norm * q / levels as f32);
    }
    // wire format: one f32 norm + per-coordinate sign+level. For levels
    // ≤ 15 that's ≤ 5 bits/coord; QSGD's Elias coding does better on
    // sparse ξ but we charge the dense bound — exactly the accounting
    // the q8/q4 wire formats use for their value stream.
    let level_bits = 32 - levels.leading_zeros();
    Encoded::from_bits(decoded, quantized_value_bits(g.len(), level_bits))
}

/// TernGrad: g_i → s·sign(g_i)·b_i with b_i ~ Bernoulli(|g_i|/s), s = max|g|.
pub fn terngrad(g: &[f32], rng: &mut Pcg64) -> Encoded {
    let s = g.iter().fold(0f32, |m, v| m.max(v.abs()));
    if s == 0.0 {
        return Encoded::from_bits(vec![0.0; g.len()], SCALE_BITS);
    }
    let decoded = g
        .iter()
        .map(|&v| {
            let p = (v.abs() / s) as f64;
            if rng.f64() < p {
                v.signum() * s
            } else {
                0.0
            }
        })
        .collect();
    // 2 bits per coordinate (three levels: sign + one level bit) + the
    // scale scalar
    Encoded::from_bits(decoded, quantized_value_bits(g.len(), 1))
}

/// AMP-style half-precision round trip (2× compression, deterministic).
pub fn fp16_roundtrip(g: &[f32]) -> Encoded {
    let decoded = g.iter().map(|&v| f16_to_f32(f32_to_f16(v))).collect();
    Encoded::from_bits(decoded, g.len() as u64 * 16)
}

/// Minimal IEEE 754 binary16 conversion (round-to-nearest-even).
fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32 - 127 + 15;
    let mut frac = bits & 0x7f_ffff;
    if exp >= 31 {
        return sign | 0x7c00; // overflow → inf (NaN payloads collapse)
    }
    if exp <= 0 {
        if exp < -10 {
            return sign; // underflow → 0
        }
        // subnormal
        frac |= 0x80_0000;
        let shift = (14 - exp) as u32;
        let half = frac >> shift;
        let rem = frac & ((1 << shift) - 1);
        let round = (rem > (1 << (shift - 1)))
            || (rem == (1 << (shift - 1)) && (half & 1) == 1);
        return sign | (half as u16 + round as u16);
    }
    let half = (frac >> 13) as u16;
    let rem = frac & 0x1fff;
    let round = (rem > 0x1000) || (rem == 0x1000 && (half & 1) == 1);
    let mut out = sign | ((exp as u16) << 10) | half;
    if round {
        out = out.wrapping_add(1);
    }
    let _ = &mut exp;
    out
}

fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = match (exp, frac) {
        (0, 0) => sign,
        (0, f) => {
            // subnormal: normalize
            let shift = f.leading_zeros() - 21;
            let frac = (f << (shift + 1)) & 0x3ff;
            let exp = 127 - 15 - shift;
            sign | (exp << 23) | (frac << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, f) => sign | 0x7f80_0000 | (f << 13),
        (e, f) => sign | ((e + 127 - 15) << 23) | (f << 13),
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 0);
        (0..d).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn qsgd_is_unbiased() {
        let g = grad(256, 1);
        let mut rng = Pcg64::new(2, 0);
        let trials = 400;
        let mut mean = vec![0f64; g.len()];
        for _ in 0..trials {
            let e = qsgd(&g, 4, &mut rng);
            for (m, v) in mean.iter_mut().zip(&e.decoded) {
                *m += *v as f64 / trials as f64;
            }
        }
        let err: f64 = mean
            .iter()
            .zip(&g)
            .map(|(m, v)| (m - *v as f64).abs())
            .sum::<f64>()
            / g.len() as f64;
        assert!(err < 0.15, "bias {err}");
    }

    #[test]
    fn qsgd_volume_below_dense() {
        let g = grad(1000, 3);
        let mut rng = Pcg64::new(4, 0);
        let e = qsgd(&g, 15, &mut rng);
        assert!(e.float_equiv < 1000.0 * 0.2, "{}", e.float_equiv);
        assert_eq!(e.decoded.len(), 1000);
    }

    #[test]
    fn terngrad_three_levels_and_unbiased() {
        let g = grad(512, 5);
        let s = g.iter().fold(0f32, |m, v| m.max(v.abs()));
        let mut rng = Pcg64::new(6, 0);
        let e = terngrad(&g, &mut rng);
        for v in &e.decoded {
            assert!(*v == 0.0 || (v.abs() - s).abs() < 1e-6, "level {v}");
        }
        // unbiasedness on the mean
        let trials = 300;
        let mut mean = vec![0f64; g.len()];
        for _ in 0..trials {
            let e = terngrad(&g, &mut rng);
            for (m, v) in mean.iter_mut().zip(&e.decoded) {
                *m += *v as f64 / trials as f64;
            }
        }
        let err: f64 = mean
            .iter()
            .zip(&g)
            .map(|(m, v)| (m - *v as f64).abs())
            .sum::<f64>()
            / g.len() as f64;
        assert!(err < 0.25, "bias {err}");
    }

    #[test]
    fn zero_gradients_handled() {
        let z = vec![0f32; 64];
        let mut rng = Pcg64::new(7, 0);
        assert!(qsgd(&z, 4, &mut rng).decoded.iter().all(|&v| v == 0.0));
        assert!(terngrad(&z, &mut rng).decoded.iter().all(|&v| v == 0.0));
        // degenerate rows still pay for the scale scalar, exactly
        assert_eq!(qsgd(&z, 4, &mut rng).encoded_bits, 32);
        assert_eq!(terngrad(&z, &mut rng).encoded_bits, 32);
    }

    #[test]
    fn encoded_bits_are_exact_and_agree_with_float_equiv() {
        let g = grad(100, 11);
        let mut rng = Pcg64::new(12, 0);
        // q8-equivalent: 255 levels → 8 level bits + sign
        let e8 = qsgd(&g, 255, &mut rng);
        assert_eq!(e8.encoded_bits, 32 + 100 * 9);
        // q4-equivalent: 15 levels → 4 level bits + sign
        let e4 = qsgd(&g, 15, &mut rng);
        assert_eq!(e4.encoded_bits, 32 + 100 * 5);
        let t = terngrad(&g, &mut rng);
        assert_eq!(t.encoded_bits, 32 + 100 * 2);
        let h = fp16_roundtrip(&g);
        assert_eq!(h.encoded_bits, 100 * 16);
        for e in [&e8, &e4, &t, &h] {
            assert_eq!(e.float_equiv, e.encoded_bits as f64 / 32.0);
        }
    }

    #[test]
    fn fp16_roundtrip_accuracy() {
        let g = grad(1000, 8);
        let e = fp16_roundtrip(&g);
        for (a, b) in g.iter().zip(&e.decoded) {
            assert!((a - b).abs() <= a.abs() * 1e-3 + 1e-4, "{a} vs {b}");
        }
        assert_eq!(e.float_equiv, 500.0);
    }

    #[test]
    fn fp16_specials() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 65504.0, 1e-8, f32::INFINITY] {
            let r = f16_to_f32(f32_to_f16(v));
            if v.is_finite() && v.abs() <= 65504.0 && v.abs() >= 6.1e-5 {
                assert!((r - v).abs() <= v.abs() * 1e-3, "{v} -> {r}");
            }
        }
        assert!(f16_to_f32(f32_to_f16(f32::INFINITY)).is_infinite());
        assert_eq!(f16_to_f32(f32_to_f16(1e10)), f32::INFINITY); // overflow
    }
}
