//! The sparse fast path's contract: masking straight into
//! `SparseGrad`, `ErrorFeedback::absorb_sparse`, and
//! `aggregate_sparse_native` are **bitwise** equal to the dense
//! reference pipeline (clone → `mask_stats_native` → dense `absorb` →
//! `aggregate_native`) — per round, per coordinate, including the
//! momentum-SGD update that consumes the aggregate. If this holds at
//! every round of a multi-round error-feedback loop, the two engines
//! produce identical global models forever, which is what lets the
//! round engine run O(Σ nnz) without a correctness caveat.
//!
//! Matrix: seeds {1,2,3} × devices {1,4,8} × CR {0.01, 0.1, 1.0}, plus
//! the all-zero-gradient and single-survivor edge cases and the
//! coordinate-chunked dense variant at several widths.

use scadles::compress::{
    mask_stats_native, mask_stats_only, threshold_for_ratio, threshold_for_ratio_with,
    ErrorFeedback, SelectScratch, SparseGrad,
};
use scadles::coordinator::{
    aggregate_chunked_native, aggregate_native, aggregate_sparse_native, weights_from_batches,
};
use scadles::rng::Pcg64;

const D: usize = 700;
const ROUNDS: u64 = 10;
const LR: f32 = 0.05;
const MOMENTUM: f32 = 0.9;

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0);
    (0..d).map(|_| rng.normal() as f32).collect()
}

/// Momentum-SGD mirror of `MockBackend::update`.
fn sgd_update(params: &mut [f32], mom: &mut [f32], grad: &[f32]) {
    for ((p, m), g) in params.iter_mut().zip(mom.iter_mut()).zip(grad) {
        *m = MOMENTUM * *m + g;
        *p -= LR * *m;
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: coord {i} ({x} vs {y})");
    }
}

/// Run `rounds` of the full compressed pipeline both ways and pin every
/// cross-checkable intermediate bit-for-bit.
fn run_equivalence(seed: u64, n: usize, cr: f64) {
    let ctx = format!("seed={seed} n={n} cr={cr}");
    // dense reference state
    let mut params_d = vec![0.1f32; D];
    let mut mom_d = vec![0f32; D];
    let mut efs_d: Vec<ErrorFeedback> = (0..n).map(|_| ErrorFeedback::new(D)).collect();
    // sparse-path state: persistent per-device buffers, as the workers own
    let mut params_s = params_d.clone();
    let mut mom_s = vec![0f32; D];
    let mut efs_s: Vec<ErrorFeedback> = (0..n).map(|_| ErrorFeedback::new(D)).collect();
    let mut corrected_s: Vec<Vec<f32>> = (0..n).map(|_| vec![0f32; D]).collect();
    let mut sparse_rows: Vec<SparseGrad> = (0..n).map(|_| SparseGrad::new()).collect();
    let mut scratch = SelectScratch::new();

    let batches: Vec<usize> = (0..n).map(|i| 8 + 3 * i).collect();
    let weights = weights_from_batches(&batches);

    for round in 0..ROUNDS {
        let mut matrix = vec![0f32; n * D];
        for i in 0..n {
            let g = grad(D, seed * 10_000 + round * 100 + i as u64);

            // dense reference
            let mut corrected_d = g.clone();
            efs_d[i].correct(&mut corrected_d);
            let (_k, thresh) = threshold_for_ratio(&corrected_d, cr);
            let mut masked = corrected_d.clone();
            let (n2_d, k2_d, nnz_d) = mask_stats_native(&mut masked, thresh);
            efs_d[i].absorb(&corrected_d, &masked);
            matrix[i * D..(i + 1) * D].copy_from_slice(&masked);

            // sparse fast path over reused buffers
            corrected_s[i].copy_from_slice(&g);
            efs_s[i].correct(&mut corrected_s[i]);
            let (_k2, thresh_s) = threshold_for_ratio_with(&corrected_s[i], cr, &mut scratch);
            assert_eq!(thresh.to_bits(), thresh_s.to_bits(), "{ctx} r{round} d{i}: thresh");
            let (n2_s, k2_s, nnz_s) = mask_stats_only(&corrected_s[i], thresh_s);
            assert_eq!(n2_d.to_bits(), n2_s.to_bits(), "{ctx} r{round} d{i}: |g|2");
            assert_eq!(k2_d.to_bits(), k2_s.to_bits(), "{ctx} r{round} d{i}: |topk|2");
            assert_eq!(nnz_d, nnz_s, "{ctx} r{round} d{i}: nnz");
            sparse_rows[i].fill_from_threshold(&corrected_s[i], thresh_s, nnz_s);
            efs_s[i].absorb_sparse(&mut corrected_s[i], &sparse_rows[i]);
            assert_eq!(
                efs_d[i].residual_norm2.to_bits(),
                efs_s[i].residual_norm2.to_bits(),
                "{ctx} r{round} d{i}: residual norm"
            );
        }

        let agg_d = aggregate_native(&matrix, &weights, D);
        let agg_s = aggregate_sparse_native(&sparse_rows, &weights, D);
        assert_bits_eq(&agg_d, &agg_s, &format!("{ctx} r{round}: aggregate"));

        sgd_update(&mut params_d, &mut mom_d, &agg_d);
        sgd_update(&mut params_s, &mut mom_s, &agg_s);
        assert_bits_eq(&params_d, &params_s, &format!("{ctx} r{round}: params"));
        assert_bits_eq(&mom_d, &mom_s, &format!("{ctx} r{round}: momentum"));
    }
}

#[test]
fn sparse_path_global_models_match_dense_bit_for_bit_across_the_matrix() {
    for seed in [1u64, 2, 3] {
        for n in [1usize, 4, 8] {
            for cr in [0.01f64, 0.1, 1.0] {
                run_equivalence(seed, n, cr);
            }
        }
    }
}

#[test]
fn all_zero_gradients_survive_both_paths_identically() {
    // zero gradient → threshold 0 → *everything* is "kept": the sparse
    // view carries d explicit zeros, the dense mask keeps all-zeros,
    // and residual, aggregate and model must all stay exactly zero.
    let g = vec![0f32; 64];
    let (_k, thresh) = threshold_for_ratio(&g, 0.1);
    assert_eq!(thresh, 0.0);

    let mut masked = g.clone();
    let (n2, k2, nnz) = mask_stats_native(&mut masked, thresh);
    assert_eq!((n2, k2, nnz), (0.0, 0.0, 64));
    let mut ef_d = ErrorFeedback::new(64);
    ef_d.absorb(&g, &masked);

    let (n2s, k2s, nnzs) = mask_stats_only(&g, thresh);
    assert_eq!((n2s, k2s, nnzs), (0.0, 0.0, 64));
    let mut sparse = SparseGrad::new();
    sparse.fill_from_threshold(&g, thresh, nnzs);
    assert_eq!(sparse.nnz(), 64);
    let mut corrected = g.clone();
    let mut ef_s = ErrorFeedback::new(64);
    ef_s.absorb_sparse(&mut corrected, &sparse);

    assert_eq!(ef_d.residual_norm2, 0.0);
    assert_eq!(ef_s.residual_norm2, 0.0);
    let w = [1.0f32];
    let agg_d = aggregate_native(&masked, &w, 64);
    let agg_s = aggregate_sparse_native(std::slice::from_ref(&sparse), &w, 64);
    assert_bits_eq(&agg_d, &agg_s, "all-zero aggregate");
    assert!(agg_s.iter().all(|v| v.to_bits() == 0), "aggregate must be +0.0");
}

#[test]
fn single_survivor_edge_case_matches() {
    // k clamps to 1 at a tiny CR: exactly one coordinate crosses the
    // wire; the residual absorbs everything else.
    let mut g = vec![0.25f32; 100];
    g[37] = -9.0; // unique magnitude maximum
    let (k, thresh) = threshold_for_ratio(&g, 1e-9);
    assert_eq!(k, 1);
    assert_eq!(thresh, 9.0);

    let mut masked = g.clone();
    let (_n2, _k2, nnz) = mask_stats_native(&mut masked, thresh);
    assert_eq!(nnz, 1);
    let mut ef_d = ErrorFeedback::new(100);
    ef_d.absorb(&g, &masked);

    let mut sparse = SparseGrad::new();
    let (_s1, _s2, nnzs) = mask_stats_only(&g, thresh);
    sparse.fill_from_threshold(&g, thresh, nnzs);
    assert_eq!(sparse.nnz(), 1);
    assert_eq!(sparse.idx, vec![37]);
    assert_eq!(sparse.val, vec![-9.0]);
    let mut corrected = g.clone();
    let mut ef_s = ErrorFeedback::new(100);
    ef_s.absorb_sparse(&mut corrected, &sparse);
    assert_eq!(ef_d.residual_norm2.to_bits(), ef_s.residual_norm2.to_bits());

    let w = [1.0f32];
    let agg_d = aggregate_native(&masked, &w, 100);
    let agg_s = aggregate_sparse_native(std::slice::from_ref(&sparse), &w, 100);
    assert_bits_eq(&agg_d, &agg_s, "single-survivor aggregate");
    assert_eq!(agg_s.iter().filter(|v| **v != 0.0).count(), 1);
}

#[test]
fn chunked_dense_aggregation_matches_serial_at_every_width() {
    // large enough that the coordinate-chunked arm actually spawns
    // threads (it falls back to serial below ~4k coordinates)
    const DBIG: usize = 10_000;
    for seed in [5u64, 6] {
        for n in [1usize, 4, 8] {
            let grads: Vec<f32> =
                (0..n).flat_map(|i| grad(DBIG, seed * 100 + i as u64)).collect();
            let mut weights = weights_from_batches(&vec![10; n]);
            if n > 1 {
                weights[n - 1] = 0.0; // skipped devices must not differ either
            }
            let serial = aggregate_native(&grads, &weights, DBIG);
            for threads in [1usize, 2, 4, 8, 16] {
                let par = aggregate_chunked_native(&grads, &weights, DBIG, threads);
                assert_bits_eq(&serial, &par, &format!("seed={seed} n={n} t={threads}"));
            }
        }
    }
}
