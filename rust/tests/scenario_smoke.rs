//! Scenario smoke-matrix (CI-gated): the mock-backend trainer must run
//! panic-free with finite losses across
//! {k80-homogeneous, two-tier, constrained-uplink} × {scadles, ddl},
//! across the stream-dynamics presets {diurnal, burst, churn,
//! linkfade, burst+churn} × {scadles, ddl}, and across the fault
//! presets {crash, corrupt, byzantine} × every robust combine rule.
//!
//! This is the cheap end-to-end guard on the scenario layers: every
//! preset must thread through config → plan → workers → clock → metrics
//! without degenerate numbers, in both training modes.

use scadles::config::{
    AggPreset, DynamicsPreset, ExperimentConfig, FaultPreset, HeteroPreset, StreamPreset,
    TrainMode,
};
use scadles::coordinator::{MockBackend, Trainer, TrainerOutput};

fn run(hetero: HeteroPreset, mode: TrainMode) -> TrainerOutput {
    let cfg = ExperimentConfig::builder("mlp_c10")
        .devices(4)
        .rounds(8)
        .preset(StreamPreset::S1)
        .hetero(hetero)
        .mode(mode)
        .eval_every(4)
        .build()
        .unwrap();
    Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10)))
        .unwrap()
        .run()
        .unwrap()
}

fn matrix() -> Vec<(HeteroPreset, TrainMode)> {
    let scenarios = [
        HeteroPreset::K80Homogeneous,
        HeteroPreset::TwoTier { slow_fraction: 0.25, slowdown: 4.0 },
        HeteroPreset::ConstrainedUplink { fraction: 0.25, uplink_bps: 1e9 },
    ];
    let modes = [TrainMode::Scadles, TrainMode::Ddl];
    scenarios
        .into_iter()
        .flat_map(|h| modes.into_iter().map(move |m| (h, m)))
        .collect()
}

#[test]
fn scenario_matrix_trains_with_finite_losses() {
    for (hetero, mode) in matrix() {
        let out = run(hetero, mode);
        let ctx = format!("{hetero} × {}", mode.name());
        assert_eq!(out.logs.rounds().len(), 8, "{ctx}: round count");
        for r in out.logs.rounds() {
            assert!(r.train_loss.is_finite(), "{ctx}: loss r{} = {}", r.round, r.train_loss);
            assert!(
                r.wall_clock_s.is_finite() && r.wall_clock_s > 0.0,
                "{ctx}: clock r{} = {}",
                r.round,
                r.wall_clock_s
            );
        }
        assert!(
            out.report.final_train_loss.is_finite(),
            "{ctx}: final loss {}",
            out.report.final_train_loss
        );
        assert!(out.report.wall_clock_s > 0.0, "{ctx}");
    }
}

#[test]
fn heterogeneous_scenarios_never_beat_the_flat_cluster_clock() {
    // The scenarios only slow devices down or narrow links, so for a
    // fixed seed the virtual wall clock is bounded below by the
    // homogeneous run's (small tolerance: waits adapt to backlogs, so
    // totals can wobble by fractions of a sample's stream time).
    for mode in [TrainMode::Scadles, TrainMode::Ddl] {
        let flat = run(HeteroPreset::K80Homogeneous, mode).report.wall_clock_s;
        for hetero in [
            HeteroPreset::TwoTier { slow_fraction: 0.25, slowdown: 4.0 },
            HeteroPreset::ConstrainedUplink { fraction: 0.25, uplink_bps: 1e9 },
        ] {
            let t = run(hetero, mode).report.wall_clock_s;
            assert!(
                t >= flat * 0.95,
                "{hetero} × {}: {t} well below flat {flat}",
                mode.name()
            );
        }
    }
}

#[test]
fn faults_matrix_trains_with_finite_losses() {
    // Every fault preset × every combine rule must thread through the
    // engine panic-free; finite loss is gated everywhere except the
    // one cell documented to diverge (plain mean under byzantine rows,
    // which is exactly what the robust rules exist for).
    let fault_specs = ["crash:0.25", "corrupt:0.25", "byzantine:0.25"];
    let agg_specs = ["mean", "trimmed:0.25", "median", "krum:1"];
    for fspec in fault_specs {
        let faults: FaultPreset = fspec.parse().unwrap();
        for aspec in agg_specs {
            let agg: AggPreset = aspec.parse().unwrap();
            let cfg = ExperimentConfig::builder("mlp_c10")
                .devices(4)
                .rounds(8)
                .preset(StreamPreset::S1)
                .faults(faults)
                .agg(agg)
                .eval_every(4)
                .build()
                .unwrap();
            let out = Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10)))
                .unwrap()
                .run()
                .unwrap();
            let ctx = format!("{fspec} × {aspec}");
            assert_eq!(out.logs.rounds().len(), 8, "{ctx}: round count");
            let loss_may_diverge =
                matches!(faults, FaultPreset::Byzantine { .. }) && matches!(agg, AggPreset::Mean);
            for r in out.logs.rounds() {
                if !loss_may_diverge {
                    assert!(
                        r.train_loss.is_finite(),
                        "{ctx}: loss r{} = {}",
                        r.round,
                        r.train_loss
                    );
                }
                assert!(
                    r.wall_clock_s.is_finite() && r.wall_clock_s > 0.0,
                    "{ctx}: clock r{} = {}",
                    r.round,
                    r.wall_clock_s
                );
                assert!(
                    r.rejected_devices + r.committed_devices + r.dropped_devices <= 4,
                    "{ctx}: device ledger overflow at r{}",
                    r.round
                );
            }
            let counters = out.fault_counts.expect("fault injector active");
            assert!(counters.total() > 0, "{ctx}: preset injected nothing over 32 device-rounds");
            match faults {
                FaultPreset::Crash { .. } => assert_eq!(
                    counters.total(),
                    counters.crashes,
                    "{ctx}: crash preset injected non-crash faults"
                ),
                FaultPreset::Corrupt { .. } => assert_eq!(
                    counters.total(),
                    counters.corrupt_rows,
                    "{ctx}: corrupt preset injected non-corrupt faults"
                ),
                FaultPreset::Byzantine { .. } => assert_eq!(
                    counters.total(),
                    counters.byzantine_rows,
                    "{ctx}: byzantine preset injected non-byzantine faults"
                ),
                _ => {}
            }
        }
    }
}

#[test]
fn dynamics_matrix_trains_with_finite_losses() {
    let presets = [
        "diurnal:0.8:20",
        "burst:4:0.25:5:10",
        "churn:0.5:20:0.5",
        "linkfade:0.1:20",
        "burst:4:0.25:5:10+churn:0.5:20:0.5",
    ];
    for spec in presets {
        let dynamics: DynamicsPreset = spec.parse().unwrap();
        for mode in [TrainMode::Scadles, TrainMode::Ddl] {
            let cfg = ExperimentConfig::builder("mlp_c10")
                .devices(4)
                .rounds(8)
                .preset(StreamPreset::S1)
                .dynamics(dynamics.clone())
                .mode(mode)
                .eval_every(4)
                .build()
                .unwrap();
            let out = Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10)))
                .unwrap()
                .run()
                .unwrap();
            let ctx = format!("{dynamics} × {}", mode.name());
            assert_eq!(out.logs.rounds().len(), 8, "{ctx}: round count");
            for r in out.logs.rounds() {
                assert!(r.train_loss.is_finite(), "{ctx}: loss r{} = {}", r.round, r.train_loss);
                assert!(
                    r.wall_clock_s.is_finite() && r.wall_clock_s > 0.0,
                    "{ctx}: clock r{} = {}",
                    r.round,
                    r.wall_clock_s
                );
                assert!(r.rate_est.is_finite() && r.rate_est >= 0.0, "{ctx}: rate_est");
                assert!(r.active_devices <= 4, "{ctx}: active_devices");
            }
            assert_eq!(
                out.timeline.rows().len(),
                8 * 4,
                "{ctx}: one timeline row per device-round"
            );
            for row in out.timeline.rows() {
                assert!(
                    row.effective_rate.is_finite() && row.effective_rate >= 0.0,
                    "{ctx}: effective rate {}",
                    row.effective_rate
                );
                if !row.active {
                    assert_eq!(row.batch, 0, "{ctx}: departed device trained");
                }
            }
        }
    }
}
