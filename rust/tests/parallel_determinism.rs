//! The parallel round engine's determinism contract: for a fixed seed,
//! a run is bitwise identical at every worker-pool width. Parallelism is
//! allowed to change *scheduling only* — all cross-device reductions
//! happen in fixed device order on the coordinator thread.
//!
//! Matrix: seeds {1,2,3} x devices {1,4,8} x engine paths {plain,
//! truncation, Top-k compression, Top-k + error feedback, Top-k at
//! CR=0.01 always-compress (single-survivor sparse scatter), Top-k at
//! CR=1.0 (whole-row sparse view), DDL baseline, two heterogeneous
//! cluster profiles, two stream-dynamics scenarios (diurnal+topk,
//! burst+churn), three synchronization policies (ksync:0.75+two-tier,
//! stale:2+diurnal, local:4), two quantized wire formats (q8+topk
//! always-compress, q4+ksync:0.75+two-tier)} x pool widths {1
//! (sequential), 4, 8}.
//! The heterogeneous cases pin the scenario layer's per-device-substream
//! sampling, the dynamics cases pin the time-varying process layer
//! (effective rates, membership, counters), and the policy cases pin
//! the synchronization layer (commit sets, staleness counters, local
//! steps): none may depend on pool width. Every compressed case runs
//! the sparse fast path (O(Σ nnz) aggregation straight from
//! worker-owned `SparseGrad` views) and every dense case the
//! coordinate-chunked parallel aggregation, so this matrix is also the
//! determinism contract for both.
//!
//! The final section pins the resilient coordinator runtime: a lossy
//! transport must not move a training bit at any pool width, evictions
//! and snapshot replays must be deterministic, and the checkpoint
//! fingerprint must cover the control-plane config.

use scadles::buffer::BufferPolicy;
use scadles::config::{
    CompressionConfig, DynamicsPreset, ExperimentConfig, HeteroPreset, StreamPreset, SyncPreset,
    TrainMode, WirePreset,
};
use scadles::coordinator::{MockBackend, Trainer, TrainerOutput};
use scadles::metrics::RoundLog;

#[derive(Clone)]
struct Case {
    name: &'static str,
    mode: TrainMode,
    policy: BufferPolicy,
    compression: Option<CompressionConfig>,
    hetero: HeteroPreset,
    dynamics: DynamicsPreset,
    sync: SyncPreset,
    wire: WirePreset,
}

fn cases() -> Vec<Case> {
    vec![
    Case {
        name: "plain",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: None,
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "truncation",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: None,
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "topk",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: Some(CompressionConfig {
            ratio: 0.1,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: false,
        }),
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "topk+ef",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: Some(CompressionConfig {
            ratio: 0.05,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        // sparse fast path at an aggressive CR: k = ceil(0.01·d) = 1 at
        // d=96, the single-survivor scatter every round
        name: "topk-aggressive",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: Some(CompressionConfig {
            ratio: 0.01,
            delta: 10.0, // always compress: every round takes the sparse path
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        // CR=1.0: threshold 0, the sparse view carries the whole row
        // (explicit zeros included) — the dense-equivalence edge
        name: "topk-cr1",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: Some(CompressionConfig {
            ratio: 1.0,
            delta: 10.0,
            ewma_alpha: 0.3,
            error_feedback: false,
        }),
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "ddl",
        wire: WirePreset::F32,
        mode: TrainMode::Ddl,
        policy: BufferPolicy::Persistence,
        compression: None,
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "two-tier",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: None,
        hetero: HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 },
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "lognormal+topk",
        wire: WirePreset::F32,
        mode: TrainMode::Ddl,
        policy: BufferPolicy::Truncation,
        compression: Some(CompressionConfig {
            ratio: 0.1,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::LognormalCompute { sigma: 0.6 },
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "diurnal+topk",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: Some(CompressionConfig {
            ratio: 0.1,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Diurnal { amplitude: 0.8, period_s: 15.0 },
        sync: SyncPreset::Bsp,
    },
    Case {
        name: "burst+churn",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: None,
        hetero: HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 },
        dynamics: DynamicsPreset::Compose(vec![
            DynamicsPreset::Burst { boost: 4.0, calm: 0.25, mean_boost_s: 5.0, mean_calm_s: 10.0 },
            DynamicsPreset::Churn { fraction: 0.5, period_s: 20.0, down_fraction: 0.5 },
        ]),
        sync: SyncPreset::Bsp,
    },
    Case {
        // semi-sync commit set over a skewed cluster: the policy's
        // completion-time ranking, laggard drops and EF absorption must
        // all be pool-width independent
        name: "ksync+two-tier",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: Some(CompressionConfig {
            ratio: 0.1,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 },
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::KSync { frac_pm: 750 },
    },
    Case {
        // bounded staleness under a moving stream: per-device staleness
        // counters, discounts and forced syncs layered on the diurnal
        // rate cycle
        name: "stale+diurnal",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: None,
        hetero: HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 },
        dynamics: DynamicsPreset::Diurnal { amplitude: 0.6, period_s: 20.0 },
        sync: SyncPreset::Stale { bound: 2 },
    },
    Case {
        // the quantized q8 wire on the always-compress sparse path:
        // encode → decode → EF absorb adds one stochastic-rounding draw
        // per survivor, and that RNG cursor (like the measured
        // sync-bytes counter) must be pool-width independent
        name: "q8+topk",
        wire: WirePreset::Q8,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: Some(CompressionConfig {
            ratio: 0.1,
            delta: 10.0, // always compress: the wire codec runs every round
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    },
    Case {
        // the 4-bit wire under a semi-sync commit set over a skewed
        // cluster: laggard EF absorption runs on *dequantized* values,
        // layered on ksync's completion ranking
        name: "q4+ksync:0.75",
        wire: WirePreset::Q4,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: Some(CompressionConfig {
            ratio: 0.1,
            delta: 10.0,
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 },
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::KSync { frac_pm: 750 },
    },
    Case {
        // FedAvg-as-a-policy: the local-step round shape through the
        // same engine, streams and report
        name: "local:4",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: None,
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Local { steps: 4 },
    },
    ]
}

fn run(case: &Case, seed: u64, devices: usize, threads: usize) -> TrainerOutput {
    let mut b = ExperimentConfig::builder("mlp_c10")
        .devices(devices)
        .rounds(12)
        .seed(seed)
        .preset(StreamPreset::S1)
        .mode(case.mode)
        .buffer_policy(case.policy)
        .hetero(case.hetero)
        .dynamics(case.dynamics.clone())
        .sync(case.sync)
        .wire(case.wire)
        .rate_jitter(0.2)
        .eval_every(4)
        .worker_threads(threads);
    if let Some(c) = case.compression {
        b = b.compression(c);
    }
    let cfg = b.build().unwrap();
    Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10)))
        .unwrap()
        .run()
        .unwrap()
}

/// Like [`run`], but with in-memory span capture on; returns the run
/// output and the serialized Chrome trace (virtual-time event stream).
fn run_traced(case: &Case, seed: u64, devices: usize, threads: usize) -> (TrainerOutput, String) {
    let mut b = ExperimentConfig::builder("mlp_c10")
        .devices(devices)
        .rounds(12)
        .seed(seed)
        .preset(StreamPreset::S1)
        .mode(case.mode)
        .buffer_policy(case.policy)
        .hetero(case.hetero)
        .dynamics(case.dynamics.clone())
        .sync(case.sync)
        .wire(case.wire)
        .rate_jitter(0.2)
        .eval_every(4)
        .worker_threads(threads)
        .trace_capture(true);
    if let Some(c) = case.compression {
        b = b.compression(c);
    }
    let cfg = b.build().unwrap();
    let mut t = Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10))).unwrap();
    let out = t.run().unwrap();
    let trace = scadles::obs::chrome_trace_string(t.trace().unwrap().events());
    (out, trace)
}

/// Bitwise f64 equality that treats NaN == NaN (unevaluated rounds log
/// NaN test accuracy).
fn feq(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

fn assert_logs_identical(a: &RoundLog, b: &RoundLog, ctx: &str) {
    assert_eq!(a.round, b.round, "{ctx}: round index");
    assert!(feq(a.wall_clock_s, b.wall_clock_s), "{ctx}: wall clock");
    assert_eq!(a.global_batch, b.global_batch, "{ctx}: global batch");
    assert!(feq(a.train_loss, b.train_loss), "{ctx}: train loss");
    assert!(feq(a.train_top1, b.train_top1), "{ctx}: train top1");
    assert!(feq(a.train_top5, b.train_top5), "{ctx}: train top5");
    assert!(feq(a.test_top1, b.test_top1), "{ctx}: test top1");
    assert!(feq(a.test_top5, b.test_top5), "{ctx}: test top5");
    assert!(feq(a.lr, b.lr), "{ctx}: lr");
    assert_eq!(a.buffered_samples, b.buffered_samples, "{ctx}: buffered");
    assert_eq!(a.floats_sent, b.floats_sent, "{ctx}: floats sent");
    assert_eq!(a.compressed, b.compressed, "{ctx}: compressed flag");
    assert_eq!(a.injection_bytes, b.injection_bytes, "{ctx}: injection");
    assert_eq!(a.straggler_device, b.straggler_device, "{ctx}: straggler device");
    assert_eq!(a.straggler_cause, b.straggler_cause, "{ctx}: straggler cause");
    assert_eq!(a.active_devices, b.active_devices, "{ctx}: active devices");
    assert!(feq(a.rate_est, b.rate_est), "{ctx}: rate estimate");
    assert_eq!(a.committed_devices, b.committed_devices, "{ctx}: committed devices");
    assert_eq!(a.dropped_devices, b.dropped_devices, "{ctx}: dropped devices");
}

fn assert_outputs_identical(a: &TrainerOutput, b: &TrainerOutput, ctx: &str) {
    assert_eq!(a.rates, b.rates, "{ctx}: sampled rates");
    assert_eq!(a.sync_bytes, b.sync_bytes, "{ctx}: measured sync bytes");
    let (ra, rb) = (&a.report, &b.report);
    assert!(feq(ra.wall_clock_s, rb.wall_clock_s), "{ctx}: report wall clock");
    assert!(
        feq(ra.final_train_loss, rb.final_train_loss),
        "{ctx}: report final loss"
    );
    assert!(feq(ra.best_test_top5, rb.best_test_top5), "{ctx}: best top5");
    assert!(feq(ra.cnc_ratio, rb.cnc_ratio), "{ctx}: cnc ratio");
    assert_eq!(
        ra.total_floats_sent, rb.total_floats_sent,
        "{ctx}: total floats"
    );
    assert_eq!(
        ra.buffer.final_samples, rb.buffer.final_samples,
        "{ctx}: buffer final"
    );
    assert_eq!(
        ra.buffer.peak_samples, rb.buffer.peak_samples,
        "{ctx}: buffer peak"
    );
    assert_eq!(ra.injection_bytes, rb.injection_bytes, "{ctx}: injection");
    let (la, lb) = (a.logs.rounds(), b.logs.rounds());
    assert_eq!(la.len(), lb.len(), "{ctx}: round count");
    for (x, y) in la.iter().zip(lb) {
        assert_logs_identical(x, y, ctx);
    }
    let (ta, tb) = (a.timeline.rows(), b.timeline.rows());
    assert_eq!(ta.len(), tb.len(), "{ctx}: timeline rows");
    for (x, y) in ta.iter().zip(tb) {
        assert_eq!(x.device, y.device, "{ctx}: timeline device");
        assert_eq!(x.batch, y.batch, "{ctx}: timeline batch");
        assert!(feq(x.wait_s, y.wait_s), "{ctx}: timeline wait");
        assert!(feq(x.compute_s, y.compute_s), "{ctx}: timeline compute");
        assert!(
            feq(x.effective_rate, y.effective_rate),
            "{ctx}: timeline effective rate"
        );
        assert_eq!(x.active, y.active, "{ctx}: timeline active");
        assert_eq!(x.participated, y.participated, "{ctx}: timeline participated");
        assert_eq!(x.staleness, y.staleness, "{ctx}: timeline staleness");
        assert_eq!(x.straggler, y.straggler, "{ctx}: timeline straggler");
        assert_eq!(x.cause, y.cause, "{ctx}: timeline cause");
    }
    assert_eq!(a.dynamics, b.dynamics, "{ctx}: dynamics counters");
    assert_eq!(a.fault_counts, b.fault_counts, "{ctx}: fault counters");
}

#[test]
fn sequential_and_parallel_reports_are_bitwise_identical() {
    for case in cases() {
        for seed in [1u64, 2, 3] {
            for devices in [1usize, 4, 8] {
                let sequential = run(&case, seed, devices, 1);
                for threads in [4usize, 8] {
                    let parallel = run(&case, seed, devices, threads);
                    let ctx = format!(
                        "{} seed={seed} devices={devices} threads={threads}",
                        case.name
                    );
                    assert_outputs_identical(&sequential, &parallel, &ctx);
                }
            }
        }
    }
}

#[test]
fn auto_width_matches_sequential() {
    // worker_threads = 0 resolves to the host's core count — whatever it
    // is, the run must still be bitwise identical to the 1-thread engine.
    let case = cases()[3].clone(); // topk+ef exercises the most per-device state
    let sequential = run(&case, 42, 8, 1);
    let auto = run(&case, 42, 8, 0);
    assert_outputs_identical(&sequential, &auto, "auto-width seed=42 devices=8");
}

#[test]
fn static_dynamics_reproduce_the_frozen_profile_engine_bitwise() {
    // The acceptance regression: `--dynamics static` (the default) and
    // an identity modulation (amplitude-0 diurnal + fraction-0 churn +
    // floor-1 linkfade, which runs the whole dynamics path — producer
    // retargeting, retention re-derivation, effective-ring pricing) must
    // be bitwise indistinguishable, at sequential and parallel widths.
    let fixed = cases()[3].clone(); // topk+ef over truncation
    let mut identity = fixed.clone();
    identity.dynamics = "diurnal:0+churn:0+linkfade:1".parse().unwrap();
    for threads in [1usize, 4, 8] {
        let a = run(&fixed, 7, 8, threads);
        let b = run(&identity, 7, 8, threads);
        assert_outputs_identical(&a, &b, &format!("static-vs-identity threads={threads}"));
    }
}

#[test]
fn bsp_policy_reproduces_seed_trainer_bitwise() {
    // The refactor's acceptance regression. The pre-refactor trainer's
    // trajectory is pinned two ways:
    //
    // 1. `ksync:1.0` runs the *entire* policy machinery — completion
    //    ranking, commit-set selection, masked weight recomputation,
    //    participation-filtered barriers and rings — at its identity
    //    point (k = m drops nobody), and must be bitwise
    //    indistinguishable from `bsp`, which routes the seed trainer's
    //    exact code paths. Any behavioural drift the policy layer
    //    introduced into the shared phases would split the two.
    // 2. Every bsp round's timing must still satisfy the seed engine's
    //    analytic pricing identities (clock = wait + compute + sync per
    //    round under the homogeneous default; the same formulas the
    //    pre-refactor loss/timing trajectory was built from).
    let exercised = Case {
        name: "bsp-vs-ksync1",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: Some(CompressionConfig {
            ratio: 0.05,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 },
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    };
    let mut identity = exercised.clone();
    identity.sync = SyncPreset::KSync { frac_pm: 1000 };
    for threads in [1usize, 4, 8] {
        let bsp = run(&exercised, 7, 8, threads);
        let ksync1 = run(&identity, 7, 8, threads);
        // labels differ by design (ksync:1 is tagged); everything the
        // engine computed must not
        assert_outputs_identical(&bsp, &ksync1, &format!("bsp-vs-ksync1 threads={threads}"));
    }
    // the analytic per-round pricing identity on the homogeneous default
    let plain = Case {
        name: "bsp-analytic",
        wire: WirePreset::F32,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Persistence,
        compression: None,
        hetero: HeteroPreset::K80Homogeneous,
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::Bsp,
    };
    let out = run(&plain, 1, 4, 1);
    let mut prev = 0.0f64;
    for r in out.logs.rounds() {
        assert!(r.wall_clock_s > prev, "clock must advance every round");
        prev = r.wall_clock_s;
        assert_eq!(r.dropped_devices, 0, "bsp drops nobody (r{})", r.round);
        assert_eq!(
            r.committed_devices,
            out.timeline
                .rows()
                .iter()
                .filter(|row| row.round == r.round && row.batch > 0)
                .count(),
            "bsp commits every trained device (r{})",
            r.round
        );
    }
    // bsp rows are never stale and never withheld
    assert_eq!(out.timeline.withheld_rounds(), 0);
    assert_eq!(out.timeline.max_staleness(), 0);
    assert!(out.timeline.rows().iter().all(|row| row.participated == (row.batch > 0)));
}

#[test]
fn chunked_dense_aggregation_in_the_round_engine_is_width_invariant() {
    // The matrix above runs a tiny mock gradient (d=96), below the
    // coordinate-chunked aggregation's serial cutoff; this case uses a
    // d large enough that dense-round aggregation actually fans the
    // coordinate range over the pool — and must still be bitwise equal
    // to the sequential engine.
    let mk = |threads: usize| {
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(4)
            .rounds(6)
            .seed(9)
            .preset(StreamPreset::S1)
            .eval_every(3)
            .worker_threads(threads)
            .build()
            .unwrap();
        Trainer::with_backend(&cfg, Box::new(MockBackend::new(8192, 10)))
            .unwrap()
            .run()
            .unwrap()
    };
    let sequential = mk(1);
    for threads in [2usize, 4] {
        let parallel = mk(threads);
        assert_outputs_identical(
            &sequential,
            &parallel,
            &format!("chunked-dense threads={threads}"),
        );
    }
}

#[test]
fn checkpoint_kill_and_restore_is_bitwise_identical_to_uninterrupted() {
    // The checkpoint acceptance regression: a run killed after round 6
    // and resumed from its checkpoint must finish bitwise identical to
    // the uninterrupted run — across pool widths and under a policy
    // that carries cross-round state (ksync's EF-absorbed laggards).
    // The config layers compression + error feedback so the residuals,
    // the adaptive gate and the RNG cursors all have to survive the
    // round trip; the q8 leg additionally pins the per-worker wire-RNG
    // cursors and the sync-bits counter across the kill/restore.
    let compression = CompressionConfig {
        ratio: 0.1,
        delta: 0.5,
        ewma_alpha: 0.3,
        error_feedback: true,
    };
    for (sync_spec, wire_spec) in
        [("bsp", "f32"), ("ksync:0.75", "f32"), ("bsp", "q8"), ("ksync:0.75", "q4")]
    {
        let sync: SyncPreset = sync_spec.parse().unwrap();
        for threads in [1usize, 4, 8] {
            let cfg = ExperimentConfig::builder("mlp_c10")
                .devices(8)
                .rounds(12)
                .seed(11)
                .preset(StreamPreset::S1)
                .buffer_policy(BufferPolicy::Truncation)
                .compression(compression)
                .hetero(HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 })
                .sync(sync)
                .wire(wire_spec.parse().unwrap())
                .rate_jitter(0.2)
                .eval_every(4)
                .worker_threads(threads)
                .build()
                .unwrap();
            let mk = || {
                Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10))).unwrap()
            };
            let uninterrupted = {
                let mut t = mk();
                t.run().unwrap()
            };
            let path = std::env::temp_dir().join(format!(
                "scadles_ckpt_det_{sync_spec}_{wire_spec}_{threads}_{}.ckpt",
                std::process::id()
            ));
            {
                // the "killed" run: 6 rounds, checkpoint, drop the trainer
                let mut t = mk();
                while t.rounds_completed() < 6 {
                    t.round().unwrap();
                }
                t.save_checkpoint(&path).unwrap();
            }
            let resumed = {
                let mut t = mk();
                t.restore_checkpoint(&path).unwrap();
                assert_eq!(t.rounds_completed(), 6, "{sync_spec}: resumed round cursor");
                t.run().unwrap()
            };
            std::fs::remove_file(&path).ok();
            assert_outputs_identical(
                &uninterrupted,
                &resumed,
                &format!("checkpoint {sync_spec} wire={wire_spec} threads={threads}"),
            );
        }
    }
}

#[test]
fn traced_event_streams_are_bitwise_identical_across_pool_widths() {
    // The tracing determinism contract: every span/instant timestamp is
    // virtual time, every recorder call runs on the coordinator thread
    // in fixed device order — so the serialized Chrome trace must be
    // byte-identical at every pool width. Three engine shapes: the seed
    // BSP path, a semi-sync commit set over a skewed cluster, and the
    // quantized wire on the always-compress sparse path.
    let all = cases();
    let traced: Vec<&Case> = all
        .iter()
        .filter(|c| matches!(c.name, "plain" | "ksync+two-tier" | "q8+topk"))
        .collect();
    assert_eq!(traced.len(), 3, "traced case selection drifted");
    for case in traced {
        let (_, sequential) = run_traced(case, 11, 8, 1);
        assert!(
            sequential.contains("\"ph\":\"X\"") && sequential.contains("\"ph\":\"i\""),
            "{}: trace has no spans/instants",
            case.name
        );
        for threads in [4usize, 8] {
            let (_, parallel) = run_traced(case, 11, 8, threads);
            assert_eq!(
                sequential, parallel,
                "{}: traced virtual-time stream differs at pool width {threads}",
                case.name
            );
        }
    }
}

#[test]
fn traced_kill_and_resume_reproduces_the_virtual_time_event_stream() {
    // Trace sequence numbers and the counter registry ride the
    // checkpoint: a run killed after round 6 and resumed must emit
    // exactly the remaining tail of the uninterrupted run's event
    // stream, so pre-kill + post-resume concatenate to the full trace.
    let case = Case {
        name: "traced-ckpt",
        wire: WirePreset::Q8,
        mode: TrainMode::Scadles,
        policy: BufferPolicy::Truncation,
        compression: Some(CompressionConfig {
            ratio: 0.1,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: true,
        }),
        hetero: HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 },
        dynamics: DynamicsPreset::Static,
        sync: SyncPreset::KSync { frac_pm: 750 },
    };
    for threads in [1usize, 4] {
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(8)
            .rounds(12)
            .seed(11)
            .preset(StreamPreset::S1)
            .buffer_policy(case.policy)
            .compression(case.compression.unwrap())
            .hetero(case.hetero)
            .sync(case.sync)
            .wire(case.wire)
            .rate_jitter(0.2)
            .eval_every(4)
            .worker_threads(threads)
            .trace_capture(true)
            .build()
            .unwrap();
        let mk = || Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10))).unwrap();
        let (full_events, full_counters) = {
            let mut t = mk();
            t.run().unwrap();
            let tr = t.trace().unwrap();
            (
                tr.events().to_vec(),
                scadles::obs::prometheus_string(tr.registry()),
            )
        };
        let path = std::env::temp_dir().join(format!(
            "scadles_ckpt_trace_{threads}_{}.ckpt",
            std::process::id()
        ));
        let prefix = {
            let mut t = mk();
            while t.rounds_completed() < 6 {
                t.round().unwrap();
            }
            t.save_checkpoint(&path).unwrap();
            t.trace().unwrap().events().to_vec()
        };
        let (tail, resumed_counters) = {
            let mut t = mk();
            t.restore_checkpoint(&path).unwrap();
            t.run().unwrap();
            let tr = t.trace().unwrap();
            (
                tr.events().to_vec(),
                scadles::obs::prometheus_string(tr.registry()),
            )
        };
        std::fs::remove_file(&path).ok();
        let mut stitched = prefix;
        stitched.extend(tail);
        assert_eq!(
            stitched.len(),
            full_events.len(),
            "threads={threads}: stitched event count"
        );
        assert_eq!(
            scadles::obs::chrome_trace_string(&stitched),
            scadles::obs::chrome_trace_string(&full_events),
            "threads={threads}: kill+resume virtual-time stream diverged"
        );
        // the registry rides along too: final snapshots are identical
        assert_eq!(
            resumed_counters, full_counters,
            "threads={threads}: kill+resume counter snapshot diverged"
        );
    }
}

#[test]
fn corrupt_and_truncated_checkpoints_error_instead_of_panicking() {
    let cfg = ExperimentConfig::builder("mlp_c10")
        .devices(4)
        .rounds(8)
        .seed(3)
        .preset(StreamPreset::S1)
        .eval_every(4)
        .build()
        .unwrap();
    let mk = || Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10))).unwrap();
    let path = std::env::temp_dir().join(format!(
        "scadles_ckpt_corrupt_{}.ckpt",
        std::process::id()
    ));
    {
        let mut t = mk();
        while t.rounds_completed() < 4 {
            t.round().unwrap();
        }
        t.save_checkpoint(&path).unwrap();
    }
    let valid = std::fs::read(&path).unwrap();

    // truncated mid-payload: the header's length check catches it
    std::fs::write(&path, &valid[..valid.len() - 7]).unwrap();
    let err = mk().restore_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("truncated checkpoint"), "got: {err}");

    // garbage magic: refused before anything is parsed
    let mut garbage = valid.clone();
    garbage[0] ^= 0xFF;
    std::fs::write(&path, &garbage).unwrap();
    let err = mk().restore_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("not a ScaDLES checkpoint"), "got: {err}");

    // payload cut short but with a *matching* header length — survives
    // every header check, so the parse itself runs out of bytes
    // mid-stream and must surface an Err (never a panic or a silent
    // partial restore)
    let mut short = valid[..valid.len() - 64].to_vec();
    let len = (short.len() - 32) as u64;
    short[24..32].copy_from_slice(&len.to_le_bytes());
    std::fs::write(&path, &short).unwrap();
    assert!(
        mk().restore_checkpoint(&path).is_err(),
        "mid-stream truncation must error"
    );

    // missing file
    std::fs::remove_file(&path).unwrap();
    let err = mk().restore_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("reading checkpoint"), "got: {err}");
}

// ===========================================================================
// Resilient coordinator runtime (rendezvous / heartbeat / witness-quorum)
// ===========================================================================

use scadles::config::NetPreset;
use scadles::coordinator::{CoordinatorRuntime, RuntimeOpts, RuntimeState};

/// Drive a full run through the coordinator runtime's state machine and
/// return the output plus the final parameter vector's bit patterns.
/// The config layers compression + EF + a skewed cluster + a semi-sync
/// policy, so a control-plane slip that leaked into training would have
/// plenty of state to corrupt.
fn run_runtime(net: NetPreset, opts: RuntimeOpts, threads: usize) -> (TrainerOutput, Vec<u32>) {
    let cfg = ExperimentConfig::builder("mlp_c10")
        .devices(8)
        .rounds(12)
        .seed(11)
        .preset(StreamPreset::S1)
        .buffer_policy(BufferPolicy::Truncation)
        .compression(CompressionConfig {
            ratio: 0.1,
            delta: 0.5,
            ewma_alpha: 0.3,
            error_feedback: true,
        })
        .hetero(HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 })
        .sync(SyncPreset::KSync { frac_pm: 750 })
        .net(net)
        .rate_jitter(0.2)
        .eval_every(4)
        .worker_threads(threads)
        .build()
        .unwrap();
    let mut rt =
        CoordinatorRuntime::with_opts(&cfg, Box::new(MockBackend::new(96, 10)), opts).unwrap();
    let out = rt.run().unwrap();
    assert_eq!(rt.state(), RuntimeState::Finished, "net={net:?} threads={threads}");
    let bits = rt.engine().params().iter().map(|p| p.to_bits()).collect();
    (out, bits)
}

#[test]
fn lossy_runtime_model_is_bitwise_the_lossless_model_at_every_pool_width() {
    // The runtime's keystone: 10% drops + delays on every control
    // message change the retry patterns and the control-plane ledger —
    // and not one bit of the trained model — at pool widths 1, 4, 8.
    let (ref_out, ref_bits) = run_runtime(NetPreset::None, RuntimeOpts::default(), 1);
    assert_eq!(ref_out.resilience, Default::default(), "--net none must tally nothing");
    let mut lossy_ledger = None;
    for threads in [1usize, 4, 8] {
        let (out, bits) = run_runtime(NetPreset::None, RuntimeOpts::default(), threads);
        assert_eq!(bits, ref_bits, "lossless params drifted at width {threads}");
        assert_outputs_identical(&ref_out, &out, &format!("runtime lossless threads={threads}"));

        let (out, bits) =
            run_runtime(NetPreset::lossy(0.1, 0.5, 3), RuntimeOpts::default(), threads);
        assert_eq!(bits, ref_bits, "lossy params differ from lossless at width {threads}");
        assert_outputs_identical(&ref_out, &out, &format!("runtime lossy threads={threads}"));
        assert!(out.resilience.witness_acks > 0, "no round ever attested");
        assert_eq!(out.resilience.round_replays, 0, "plain loss must never force a replay");
        // the control-plane ledger itself is pool-width invariant too:
        // transport draws are pure in (seed, device, round)
        match lossy_ledger {
            None => lossy_ledger = Some(out.resilience),
            Some(l) => assert_eq!(out.resilience, l, "ledger drifted at width {threads}"),
        }
    }
}

#[test]
fn partitioned_devices_are_evicted_and_their_gradients_withheld() {
    // A partitioned device misses every heartbeat of its round and is
    // evicted from the barrier: its (already-trained) gradient folds
    // into the error-feedback residual through the same withhold path
    // as a K-sync laggard. That *does* move the model — eviction is a
    // membership change, not transport noise — so the claim here is
    // the eviction ledger plus pool-width invariance, not lossless
    // equivalence.
    let mk = |threads: usize| {
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(8)
            .rounds(12)
            .seed(11)
            .preset(StreamPreset::S1)
            .compression(CompressionConfig {
                ratio: 0.1,
                delta: 0.5,
                ewma_alpha: 0.3,
                error_feedback: true,
            })
            .net(NetPreset::partition(0.2))
            .eval_every(4)
            .worker_threads(threads)
            .build()
            .unwrap();
        let mut rt = CoordinatorRuntime::new(&cfg, Box::new(MockBackend::new(96, 10))).unwrap();
        let out = rt.run().unwrap();
        let partitioned = rt.net_counters().unwrap().partitioned_device_rounds;
        let bits: Vec<u32> = rt.engine().params().iter().map(|p| p.to_bits()).collect();
        (out, partitioned, bits)
    };
    let (out, partitioned, bits) = mk(1);
    assert!(partitioned > 0, "partition:0.2 never fired over 96 device-rounds");
    assert_eq!(
        out.resilience.heartbeat_misses, partitioned,
        "every partitioned device-round is exactly one heartbeat miss"
    );
    // under BSP the only drop source is the runtime's eviction mask
    let dropped: usize = out.logs.rounds().iter().map(|l| l.dropped_devices).sum();
    assert_eq!(dropped as u64, partitioned, "every miss evicts exactly its device");
    assert!(
        out.timeline.withheld_rounds() > 0,
        "evicted gradients must ride the withhold path"
    );
    assert!(out.report.final_train_loss.is_finite());
    for threads in [4usize, 8] {
        let (wout, wpart, wbits) = mk(threads);
        assert_eq!(wbits, bits, "eviction schedule drifted at width {threads}");
        assert_eq!(wpart, partitioned, "partition draws drifted at width {threads}");
        assert_outputs_identical(&out, &wout, &format!("partition threads={threads}"));
    }
}

#[test]
fn forced_quorum_failure_replays_exactly_once_and_is_bitwise_invisible() {
    // The replay path end to end: fail round 5's first commit attempt,
    // watch exactly one snapshot replay, and demand the final model is
    // still bit-for-bit the unforced run's — at every pool width.
    let lossy = NetPreset::lossy(0.1, 0.5, 3);
    for threads in [1usize, 4, 8] {
        let (clean, clean_bits) = run_runtime(lossy, RuntimeOpts::default(), threads);
        let (forced, forced_bits) = run_runtime(
            lossy,
            RuntimeOpts { force_replay_round: Some(5), ..Default::default() },
            threads,
        );
        assert_eq!(forced.resilience.round_replays, 1, "threads={threads}");
        assert_eq!(forced.logs.rounds()[5].round_replays, 1, "threads={threads}");
        assert_eq!(
            forced_bits, clean_bits,
            "replay moved a training bit (threads={threads})"
        );
        assert_outputs_identical(&clean, &forced, &format!("forced-replay threads={threads}"));
    }
}

#[test]
fn checkpoint_fingerprint_pins_net_witness_and_quorum_config() {
    // A checkpoint written under one control-plane config must refuse
    // to restore under any other: `--net`, `--witnesses` and `--quorum`
    // are all part of the fingerprinted ExperimentConfig.
    let cfg = |net: &str, witnesses: usize, quorum: usize| {
        ExperimentConfig::builder("mlp_c10")
            .devices(4)
            .rounds(8)
            .seed(3)
            .preset(StreamPreset::S1)
            .net(net.parse().unwrap())
            .witnesses(witnesses)
            .quorum(quorum)
            .eval_every(4)
            .build()
            .unwrap()
    };
    let mk = |c: &ExperimentConfig| {
        CoordinatorRuntime::new(c, Box::new(MockBackend::new(96, 10))).unwrap()
    };
    let path = std::env::temp_dir().join(format!(
        "scadles_ckpt_net_fp_{}.ckpt",
        std::process::id()
    ));
    {
        let mut rt = mk(&cfg("lossy:0.1:0.5:3", 3, 2));
        while rt.engine().rounds_completed() < 4 {
            rt.step().unwrap();
        }
        rt.save_checkpoint(&path).unwrap();
    }
    // the exact config restores and finishes
    {
        let mut rt = mk(&cfg("lossy:0.1:0.5:3", 3, 2));
        rt.restore_checkpoint(&path).unwrap();
        assert_eq!(rt.engine().rounds_completed(), 4, "resumed round cursor");
        let out = rt.run().unwrap();
        assert_eq!(out.logs.rounds().len(), 8);
    }
    // any control-plane drift is refused before a byte is parsed
    for (net, w, q) in [
        ("lossy:0.3:0.5:3", 3, 2), // different loss rate
        ("none", 3, 2),            // lossless vs lossy
        ("lossy:0.1:0.5:3", 4, 2), // witness-set size
        ("lossy:0.1:0.5:3", 3, 3), // quorum threshold
    ] {
        let err = mk(&cfg(net, w, q))
            .restore_checkpoint(&path)
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("different experiment config"),
            "net={net} witnesses={w} quorum={q}: {err}"
        );
    }
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn injection_path_is_deterministic_across_widths() {
    // injection is a serial cross-device step between the poll and train
    // phases; the donated-record routing must not depend on pool width.
    use scadles::config::InjectionConfig;
    use scadles::data::LabelMap;
    let mk = |threads: usize| {
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(6)
            .rounds(10)
            .seed(5)
            .preset(StreamPreset::S1)
            .label_map(LabelMap::NonIid { labels_per_device: 1 })
            .injection(InjectionConfig::new(0.5, 0.5))
            .eval_every(5)
            .worker_threads(threads)
            .build()
            .unwrap();
        Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10)))
            .unwrap()
            .run()
            .unwrap()
    };
    let sequential = mk(1);
    let parallel = mk(6);
    assert!(sequential.report.injection_bytes > 0);
    assert_outputs_identical(&sequential, &parallel, "injection devices=6");
}

#[test]
fn full_sampling_reproduces_seed_trainer_bitwise() {
    // The fleet-sampling acceptance anchor: `--sample 1.0` engages the
    // whole sampler machinery — the per-round Pcg64 draw, the sampled
    // mask AND-ed into device activity, the sampled-devices gauge, the
    // checkpoint cursor — at its identity point (the draw returns the
    // full fleet), and must be bitwise indistinguishable from the
    // default engine at every pool width. Any behavioural drift the
    // sampling layer introduced into the shared round phases would
    // split the two. Labels differ by design (`-sample:1.0` is tagged);
    // everything the engine computed must not.
    let mk = |threads: usize, sampled: bool| {
        let mut b = ExperimentConfig::builder("mlp_c10")
            .devices(8)
            .rounds(12)
            .seed(7)
            .preset(StreamPreset::S1)
            .buffer_policy(BufferPolicy::Truncation)
            .compression(CompressionConfig {
                ratio: 0.1,
                delta: 0.5,
                ewma_alpha: 0.3,
                error_feedback: true,
            })
            .hetero(HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 })
            .rate_jitter(0.2)
            .eval_every(4)
            .worker_threads(threads);
        if sampled {
            b = b.sample("1.0".parse().unwrap());
        }
        let cfg = b.build().unwrap();
        Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10)))
            .unwrap()
            .run()
            .unwrap()
    };
    for threads in [1usize, 4, 8] {
        let plain = mk(threads, false);
        let sampled = mk(threads, true);
        assert_outputs_identical(
            &plain,
            &sampled,
            &format!("sample-1.0-vs-default threads={threads}"),
        );
    }
}

#[test]
fn checkpoint_kill_and_restore_under_sampling_is_bitwise_identical() {
    // Kill/resume under participant sampling: the sampler's RNG cursor
    // and the sampled-set purity must survive the checkpoint round
    // trip, and the resumed run's draws for rounds 7.. must be the
    // draws the uninterrupted run made (they are pure in (seed, round),
    // so the cursor is attestation — but the checkpoint layout and the
    // config fingerprint must both cover the sampling config).
    for threads in [1usize, 4] {
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(8)
            .rounds(12)
            .seed(11)
            .preset(StreamPreset::S1)
            .buffer_policy(BufferPolicy::Truncation)
            .compression(CompressionConfig {
                ratio: 0.1,
                delta: 0.5,
                ewma_alpha: 0.3,
                error_feedback: true,
            })
            .hetero(HeteroPreset::TwoTier { slow_fraction: 0.5, slowdown: 4.0 })
            .sample("5".parse().unwrap())
            .rate_jitter(0.2)
            .eval_every(4)
            .worker_threads(threads)
            .build()
            .unwrap();
        let mk = || Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10))).unwrap();
        let uninterrupted = {
            let mut t = mk();
            t.run().unwrap()
        };
        let path = std::env::temp_dir().join(format!(
            "scadles_ckpt_sample_{threads}_{}.ckpt",
            std::process::id()
        ));
        {
            let mut t = mk();
            while t.rounds_completed() < 6 {
                t.round().unwrap();
            }
            t.save_checkpoint(&path).unwrap();
        }
        let resumed = {
            let mut t = mk();
            t.restore_checkpoint(&path).unwrap();
            assert_eq!(t.rounds_completed(), 6, "resumed round cursor");
            t.run().unwrap()
        };
        // a sampling checkpoint must not restore into a non-sampling engine
        {
            let mut plain_cfg = cfg.clone();
            plain_cfg.sample = scadles::config::SamplePreset::Full;
            let err = Trainer::with_backend(&plain_cfg, Box::new(MockBackend::new(96, 10)))
                .unwrap()
                .restore_checkpoint(&path)
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("different experiment config"),
                "fingerprint must cover --sample: {err}"
            );
        }
        std::fs::remove_file(&path).ok();
        assert_outputs_identical(
            &uninterrupted,
            &resumed,
            &format!("checkpoint sample=5 threads={threads}"),
        );
    }
}
