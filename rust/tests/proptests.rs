//! Property-based tests on coordinator invariants.
//!
//! The sandbox builds offline, so instead of the `proptest` crate this file
//! uses a small self-contained harness: each property runs against many
//! randomized cases drawn from the crate's deterministic [`Pcg64`]; on
//! failure the case seed is printed so the exact input can be replayed.

use scadles::buffer::BufferPolicy;
use scadles::compress::{mask_stats_native, threshold_for_ratio, topk_threshold};
use scadles::config::{ExperimentConfig, HeteroPreset, StreamPreset, TrainMode};
use scadles::coordinator::plan::RoundPlan;
use scadles::coordinator::{aggregate_native, weights_from_batches, MockBackend, Trainer};
use scadles::coordinator::backend::Backend;
use scadles::data::LabelMap;
use scadles::rng::{Pcg64, RateDistribution};
use scadles::runtime::BucketLadder;
use scadles::stream::{Partition, Record, Retention};

/// Run `cases` randomized checks; panics with the failing seed.
fn property(name: &str, cases: u64, mut check: impl FnMut(&mut Pcg64)) {
    for case in 0..cases {
        let seed = 0xF00D ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Pcg64::new(seed, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            check(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!("property {name:?} FAILED at case {case} (seed {seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

fn rec(seed: u64) -> Record {
    Record { offset: 0, timestamp_us: 0, label: (seed % 10) as u32, seed }
}

// ---------------------------------------------------------------------------
// aggregation invariants (Eqn. 4a/4b)
// ---------------------------------------------------------------------------

#[test]
fn prop_weights_are_a_partition_of_unity() {
    property("weights sum to 1 over active devices", 200, |rng| {
        let n = 1 + rng.below(30);
        let batches: Vec<usize> = (0..n).map(|_| rng.below(300)).collect();
        let w = weights_from_batches(&batches);
        let total: f32 = w.iter().sum();
        let active: usize = batches.iter().filter(|&&b| b > 0).count();
        if active == 0 {
            assert_eq!(total, 0.0);
        } else {
            assert!((total - 1.0).abs() < 1e-4, "sum {total}");
        }
        // weights proportional to batches
        for (i, &b) in batches.iter().enumerate() {
            if b == 0 {
                assert_eq!(w[i], 0.0);
            }
        }
    });
}

#[test]
fn prop_aggregation_bounded_by_hull() {
    property("weighted aggregate stays in the convex hull", 100, |rng| {
        let n = 1 + rng.below(8);
        let d = 1 + rng.below(64);
        let grads: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let batches: Vec<usize> = (0..n).map(|_| 1 + rng.below(100)).collect();
        let w = weights_from_batches(&batches);
        let agg = aggregate_native(&grads, &w, d);
        for j in 0..d {
            let col: Vec<f32> = (0..n).map(|i| grads[i * d + j]).collect();
            let lo = col.iter().cloned().fold(f32::INFINITY, f32::min) - 1e-4;
            let hi = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max) + 1e-4;
            assert!(agg[j] >= lo && agg[j] <= hi, "coord {j}: {} ∉ [{lo},{hi}]", agg[j]);
        }
    });
}

#[test]
fn prop_aggregation_linear_in_weights() {
    property("aggregate(αw) == α·aggregate(w)", 100, |rng| {
        let n = 1 + rng.below(6);
        let d = 1 + rng.below(32);
        let grads: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> = (0..n).map(|_| rng.f64() as f32).collect();
        let a = aggregate_native(&grads, &w, d);
        let w2: Vec<f32> = w.iter().map(|x| 2.0 * x).collect();
        let b = aggregate_native(&grads, &w2, d);
        for j in 0..d {
            assert!((b[j] - 2.0 * a[j]).abs() < 1e-3, "coord {j}");
        }
    });
}

// ---------------------------------------------------------------------------
// batching / planning invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_plan_respects_bounds_and_buckets() {
    let ladder = BucketLadder::new(vec![8, 16, 32, 64, 128, 256]).unwrap();
    property("plans stay within [b_min, b_max] and fit buckets", 200, |rng| {
        let n = 1 + rng.below(20);
        let mode = if rng.below(2) == 0 { TrainMode::Scadles } else { TrainMode::Ddl };
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(n)
            .mode(mode)
            .batch_bounds(8, 256)
            .build()
            .unwrap();
        let rates: Vec<f64> = (0..n).map(|_| 1.0 + rng.f64() * 500.0).collect();
        let backlogs: Vec<usize> = (0..n).map(|_| rng.below(2000)).collect();
        let cluster = HeteroPreset::K80Homogeneous.sample_cluster("mlp_c10", n, 0);
        let plan = RoundPlan::plan(&cfg, &ladder, &cluster, &rates, &backlogs, &vec![true; n]);
        assert_eq!(plan.devices.len(), n);
        for p in &plan.devices {
            assert!(p.batch >= 8 && p.batch <= 256, "batch {}", p.batch);
            assert!(p.bucket >= p.batch, "bucket {} < batch {}", p.bucket, p.batch);
            assert!(ladder.buckets().contains(&p.bucket));
            assert!(p.wait_s >= 0.0 && p.wait_s.is_finite());
            assert!(plan.wait_s >= p.wait_s);
        }
        assert_eq!(plan.global_batch(), plan.batches().iter().sum::<usize>());
    });
}

#[test]
fn prop_scadles_wait_bounded_by_one_second_of_stream() {
    // with b_i = clamp(S_i) and empty backlog, wait ≈ b_i/S_i ≤ ~1 s except
    // when the b_min floor binds on very slow streams
    let ladder = BucketLadder::new(vec![8, 16, 32, 64, 128, 256]).unwrap();
    property("scadles wait bounded", 200, |rng| {
        let n = 1 + rng.below(16);
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(n)
            .mode(TrainMode::Scadles)
            .build()
            .unwrap();
        let rates: Vec<f64> = (0..n).map(|_| 8.0 + rng.f64() * 500.0).collect();
        let backlogs = vec![0usize; n];
        let cluster = HeteroPreset::K80Homogeneous.sample_cluster("mlp_c10", n, 0);
        let plan = RoundPlan::plan(&cfg, &ladder, &cluster, &rates, &backlogs, &vec![true; n]);
        assert!(plan.wait_s <= 1.13, "wait {}", plan.wait_s); // b_i = round(S_i) can exceed S_i by <1
    });
}

// ---------------------------------------------------------------------------
// top-k invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_topk_threshold_keeps_k_modulo_ties() {
    property("top-k keeps ≥k and ≤k+ties elements", 200, |rng| {
        let d = 1 + rng.below(5000);
        let g: Vec<f32> = (0..d)
            .map(|_| (rng.normal() * 3.0) as f32)
            .collect();
        let k = 1 + rng.below(d);
        let t = topk_threshold(&g, k);
        let kept = g.iter().filter(|v| v.abs() >= t).count();
        let ties = g.iter().filter(|v| v.abs() == t).count();
        assert!(kept >= k, "kept {kept} < k {k}");
        assert!(kept <= k + ties, "kept {kept} > k {k} + ties {ties}");
    });
}

#[test]
fn prop_mask_preserves_energy_split() {
    property("norm² = kept² + dropped²", 100, |rng| {
        let d = 1 + rng.below(3000);
        let mut g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let orig = g.clone();
        let (_k, t) = threshold_for_ratio(&g, 0.1 + rng.f64() * 0.8);
        let (n2, k2, nnz) = mask_stats_native(&mut g, t);
        let dropped2: f64 = orig
            .iter()
            .filter(|v| v.abs() < t)
            .map(|v| (*v as f64) * (*v as f64))
            .sum();
        assert!((n2 - (k2 + dropped2)).abs() / n2.max(1e-9) < 1e-6);
        assert_eq!(nnz, g.iter().filter(|v| **v != 0.0).count());
        // masked vector only zeroed, never altered
        for (a, b) in g.iter().zip(&orig) {
            assert!(*a == 0.0 || a == b);
        }
    });
}

// ---------------------------------------------------------------------------
// quantized wire invariants (--wire q8/q4)
// ---------------------------------------------------------------------------

#[test]
fn prop_quantized_roundtrip_bounded_and_sign_preserving() {
    use scadles::compress::{QuantizedGrad, SparseGrad};
    property("q8/q4 round-trip error ≤ one level, signs survive", 150, |rng| {
        let nnz = rng.below(400);
        let bits = if rng.below(2) == 0 { 8u32 } else { 4 };
        let mut s = SparseGrad::new();
        let mut next = 0u32;
        for _ in 0..nnz {
            next += 1 + rng.below(1000) as u32; // strictly ascending indices
            s.idx.push(next);
            // mix magnitudes across orders, with exact zeros sprinkled in
            let v = if rng.below(8) == 0 {
                0.0
            } else {
                (rng.normal() as f32) * 10f32.powi(rng.below(7) as i32 - 3)
            };
            s.val.push(v);
        }
        let mut q = QuantizedGrad::default();
        q.encode(&s, bits, rng);
        let scale = s.val.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert_eq!(q.scale, scale, "scale is the survivor max-|v|");
        let levels = QuantizedGrad::levels(bits) as i16;
        assert!(q.qvals.iter().all(|&l| l.abs() <= levels), "levels in range");
        let mut out = s.val.clone();
        q.decode_into(&mut out);
        let step = if scale > 0.0 { scale / levels as f32 } else { 0.0 };
        for (v, d) in s.val.iter().zip(&out) {
            assert!(
                (v - d).abs() <= step * 1.0001,
                "|{v} − {d}| > one quantization step {step}"
            );
            assert!(
                *d == 0.0 || v.is_sign_negative() == d.is_sign_negative(),
                "sign flipped: {v} → {d}"
            );
            assert!(d.abs() <= scale * 1.0001, "decode exceeds the row scale");
        }
        // exact bit accounting: scale + (1+bits)/value + delta varints
        let expect = 32
            + nnz as u64 * (1 + bits as u64)
            + scadles::compress::delta_index_bits(&s.idx);
        assert_eq!(q.encoded_bits(&s.idx), expect);
        // and the quantized wire never costs more than the f32+u32 pair wire
        if nnz > 1 {
            assert!(q.encoded_bits(&s.idx) <= 32 + nnz as u64 * 64);
        }
    });
}

#[test]
fn prop_quantized_ef_conserves_mass_bitwise() {
    use scadles::compress::{
        mask_stats_only, threshold_for_ratio, ErrorFeedback, QuantizedGrad, SparseGrad,
    };
    property("residual + dequantized sent == corrected, bitwise", 60, |rng| {
        let d = 1 + rng.below(1500);
        let cr = [0.01, 0.1, 0.5, 1.0][rng.below(4)];
        let bits = if rng.below(2) == 0 { 8u32 } else { 4 };
        let mut ef = ErrorFeedback::new(d);
        let mut sparse = SparseGrad::new();
        let mut quant = QuantizedGrad::default();
        let mut corrected = vec![0f32; d];
        for _round in 0..3 {
            for v in corrected.iter_mut() {
                *v = rng.normal() as f32;
            }
            ef.correct(&mut corrected);
            let snapshot = corrected.clone();
            let (_k, t) = threshold_for_ratio(&corrected, cr);
            let (_n2, _k2, nnz) = mask_stats_only(&corrected, t);
            sparse.fill_from_threshold(&corrected, t, nnz);
            quant.encode(&sparse, bits, rng);
            quant.decode_into(&mut sparse.val);
            ef.absorb_quantized(&mut corrected, &sparse);
            // kept coordinates: residual is bitwise corrected − dequant;
            // dropped ones keep the corrected bits untouched
            let mut kept = vec![false; d];
            for (&i, &v) in sparse.idx.iter().zip(&sparse.val) {
                kept[i as usize] = true;
                assert_eq!(
                    ef.residual()[i as usize].to_bits(),
                    (snapshot[i as usize] - v).to_bits(),
                    "kept coord {i}"
                );
            }
            for i in 0..d {
                if !kept[i] {
                    assert_eq!(
                        ef.residual()[i].to_bits(),
                        snapshot[i].to_bits(),
                        "dropped coord {i}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_radix_select_matches_select_nth_bitwise() {
    use scadles::compress::{
        threshold_for_ratio_select_nth_with, threshold_for_ratio_with, SelectScratch,
    };
    property("radix threshold == select_nth threshold, ties included", 80, |rng| {
        let d = 1 + rng.below(4000);
        let cr = [0.01, 0.1, 0.5, 1.0][rng.below(4)];
        let g: Vec<f32> = (0..d)
            .map(|_| {
                match rng.below(10) {
                    // exact zeros of both signs and duplicated magnitudes
                    0 => 0.0,
                    1 => -0.0,
                    2 => 0.25, // deliberate tie mass
                    3 => -0.25,
                    4 => f32::MIN_POSITIVE / 2.0, // subnormal
                    _ => (rng.normal() as f32) * 10f32.powi(rng.below(9) as i32 - 4),
                }
            })
            .collect();
        let mut radix = SelectScratch::with_capacity(d);
        let mut nth = SelectScratch::with_capacity(d);
        let (k_r, t_r) = threshold_for_ratio_with(&g, cr, &mut radix);
        let (k_n, t_n) = threshold_for_ratio_select_nth_with(&g, cr, &mut nth);
        assert_eq!(k_r, k_n, "k diverged at d={d} cr={cr}");
        assert_eq!(
            t_r.to_bits(),
            t_n.to_bits(),
            "threshold bits diverged at d={d} cr={cr}: {t_r} vs {t_n}"
        );
        // identical thresholds ⇒ identical masks; spot-check the count
        let kept = g.iter().filter(|v| v.abs() >= t_r).count();
        assert!(kept >= k_r, "kept {kept} < k {k_r}");
    });
}

// ---------------------------------------------------------------------------
// stream substrate invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_partition_conservation() {
    property("produced = buffered + consumed-purged + dropped", 150, |rng| {
        let cap = 1 + rng.below(200);
        let trunc = rng.below(2) == 0;
        let retention = if trunc {
            Retention::Truncate { keep: cap }
        } else {
            Retention::Persist
        };
        let mut part = Partition::new(retention);
        let total = rng.below(1000);
        for s in 0..total {
            part.append(rec(s as u64));
        }
        assert_eq!(part.produced() as usize, total);
        assert_eq!(part.len() + part.dropped() as usize, total);
        if trunc {
            assert!(part.len() <= cap);
        } else {
            assert_eq!(part.dropped(), 0);
        }
        // offsets remain monotone and dense over the retained window
        let recs = part.read(0, total);
        for w in recs.windows(2) {
            assert_eq!(w[1].offset, w[0].offset + 1);
        }
    });
}

#[test]
fn prop_consumer_never_sees_duplicate_offsets() {
    property("poll yields strictly increasing offsets", 100, |rng| {
        use scadles::stream::{Consumer, Topic};
        let t = Topic::new("d", Retention::Truncate { keep: 64 });
        let mut c = Consumer::new(t.clone());
        let mut last: Option<u64> = None;
        for _ in 0..20 {
            t.produce((0..rng.below(100)).map(|s| rec(s as u64)));
            for r in c.poll(rng.below(50)) {
                if let Some(prev) = last {
                    assert!(r.offset > prev, "offset {} after {prev}", r.offset);
                }
                last = Some(r.offset);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// rate distributions (Table I)
// ---------------------------------------------------------------------------

#[test]
fn prop_rates_positive_for_all_presets() {
    property("sampled rates ≥ 1", 50, |rng| {
        for p in StreamPreset::all() {
            let rates = p.distribution().sample_n(rng, 64);
            assert!(rates.iter().all(|&r| r >= 1.0));
        }
        // custom distributions too
        let d = RateDistribution::Normal { mean: 1.0, std: 100.0 };
        assert!(d.sample_n(rng, 64).iter().all(|&r| r >= 1.0));
    });
}

// ---------------------------------------------------------------------------
// end-to-end trainer invariants (mock backend)
// ---------------------------------------------------------------------------

#[test]
fn prop_trainer_accounting_consistent() {
    property("round logs internally consistent", 12, |rng| {
        let preset = StreamPreset::all()[rng.below(4)];
        let mode = if rng.below(2) == 0 { TrainMode::Scadles } else { TrainMode::Ddl };
        let policy = if rng.below(2) == 0 {
            BufferPolicy::Persistence
        } else {
            BufferPolicy::Truncation
        };
        let noniid = rng.below(2) == 0;
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(2 + rng.below(6))
            .rounds(8)
            .seed(rng.next_u64())
            .preset(preset)
            .mode(mode)
            .buffer_policy(policy)
            .label_map(if noniid {
                LabelMap::NonIid { labels_per_device: 1 }
            } else {
                LabelMap::Iid
            })
            .build()
            .unwrap();
        let backend = MockBackend::new(32, 10);
        let d = backend.param_count() as u64;
        let mut t = Trainer::with_backend(&cfg, Box::new(backend)).unwrap();
        let out = t.run().unwrap();
        let logs = out.logs.rounds();
        assert_eq!(logs.len(), 8);
        let mut prev_t = 0.0;
        for log in logs {
            assert!(log.wall_clock_s > prev_t, "clock must advance");
            prev_t = log.wall_clock_s;
            assert!(log.train_loss.is_finite());
            assert!(log.lr > 0.0);
            assert!(log.global_batch > 0);
            // dense rounds move exactly active_devices * d floats
            if !log.compressed {
                assert_eq!(log.floats_sent % d, 0);
            }
            assert!(log.train_top1 <= log.train_top5 + 1e-9);
            assert!(log.train_top5 <= 1.0 + 1e-9);
        }
        // report aggregates match logs
        assert_eq!(
            out.report.total_floats_sent,
            logs.iter().map(|l| l.floats_sent).sum::<u64>()
        );
    });
}

#[test]
fn prop_truncation_never_beats_persistence_on_buffer() {
    property("truncation buffer ≤ persistence buffer", 8, |rng| {
        let seed = rng.next_u64();
        let preset = StreamPreset::all()[rng.below(4)];
        let run = |policy| {
            let cfg = ExperimentConfig::builder("mlp_c10")
                .devices(4)
                .rounds(10)
                .seed(seed)
                .preset(preset)
                .buffer_policy(policy)
                .build()
                .unwrap();
            Trainer::with_backend(&cfg, Box::new(MockBackend::new(32, 10)))
                .unwrap()
                .run()
                .unwrap()
                .report
                .buffer
                .final_samples
        };
        let p = run(BufferPolicy::Persistence);
        let t = run(BufferPolicy::Truncation);
        assert!(t <= p, "truncation {t} > persistence {p}");
    });
}
