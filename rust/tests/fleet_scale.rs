//! Fleet scale-out acceptance: sampler purity, width invariance of the
//! sampled set, and hierarchical-vs-flat aggregation equality.
//!
//! The contracts pinned here:
//!
//! - The participant draw is pure in `(seed, round)` — no history, no
//!   thread count, no call order feeds it. Two samplers with the same
//!   seed agree on every round regardless of which rounds they drew
//!   before, and the same draw comes out of `draw` and `draw_mask`.
//! - A sampled `Trainer` run is bitwise identical at every worker-pool
//!   width: the draw happens on the coordinator thread, the mask is
//!   AND-ed into device activity before any parallel phase starts.
//! - Two-tier gateway aggregation is bitwise identical to the flat
//!   reduction from the same state: gateway blocks are contiguous in
//!   device order, so the block-partitioned fold *is* the flat
//!   sequential fold. Only the sync pricing (and hence the virtual
//!   clock) differs, which is why the equality is asserted on one
//!   round from identical initial state.

use scadles::config::{ExperimentConfig, SamplePreset, StreamPreset, TierPreset};
use scadles::coordinator::fleet::SAMPLE_RNG_STREAM;
use scadles::coordinator::{FleetSampler, MockBackend, RoundEngine, Trainer};

#[test]
fn sampler_is_pure_in_seed_and_round_regardless_of_history() {
    let preset: SamplePreset = "64".parse().unwrap();
    let mut a = FleetSampler::new(preset, 1000, 42);
    let mut b = FleetSampler::new(preset, 1000, 42);
    // a draws rounds in order; b draws them shuffled and with repeats —
    // the per-round sets must agree anyway.
    let in_order: Vec<Vec<usize>> = (0..8).map(|r| a.draw(r)).collect();
    for r in [5usize, 0, 7, 3, 3, 1, 6, 2, 4, 0] {
        assert_eq!(b.draw(r), in_order[r], "round {r} draw is history-dependent");
    }
    // different seed, different draws (overwhelmingly)
    let mut c = FleetSampler::new(preset, 1000, 43);
    assert_ne!(c.draw(0), in_order[0], "seed must feed the draw");
    // different round, different draws (overwhelmingly)
    assert_ne!(in_order[0], in_order[1], "round must feed the draw");
    // the dedicated stream keeps the draw off every other consumer
    assert_eq!(SAMPLE_RNG_STREAM, 0x5A3B_1E00);
}

#[test]
fn draw_and_draw_mask_agree_and_fractions_resolve() {
    let mut by_list = FleetSampler::new("0.25".parse().unwrap(), 64, 7);
    let mut by_mask = FleetSampler::new("0.25".parse().unwrap(), 64, 7);
    assert_eq!(by_list.k(), 16);
    let mut mask = Vec::new();
    for round in 0..6 {
        let ids = by_list.draw(round);
        let n = by_mask.draw_mask(round, &mut mask);
        assert_eq!(n, ids.len(), "round {round} cardinality");
        let from_mask: Vec<usize> =
            (0..64).filter(|&i| mask[i]).collect();
        assert_eq!(from_mask, ids, "round {round} mask/list disagree");
        // sorted unique, in range
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        assert!(ids.iter().all(|&i| i < 64));
    }
}

fn sampled_cfg(threads: usize) -> ExperimentConfig {
    ExperimentConfig::builder("mlp_c10")
        .devices(8)
        .rounds(10)
        .seed(13)
        .preset(StreamPreset::S1)
        .rate_jitter(0.2)
        .eval_every(5)
        .sample("3".parse().unwrap())
        .worker_threads(threads)
        .build()
        .unwrap()
}

#[test]
fn sampled_runs_are_bitwise_identical_across_pool_widths() {
    // The sampled set and everything downstream of it (which devices
    // train, the commit set, the priced ring, the timeline rows) must
    // not depend on the worker-pool width.
    let run = |threads: usize| {
        let cfg = sampled_cfg(threads);
        let mut t = Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10))).unwrap();
        let out = t.run().unwrap();
        let bits: Vec<u32> = t.params().iter().map(|p| p.to_bits()).collect();
        (out, bits)
    };
    let (sequential, seq_bits) = run(1);
    for threads in [4usize, 8] {
        let (parallel, par_bits) = run(threads);
        // params are the strongest single invariant: every sampled
        // device's gradient fed them in fixed order
        assert_eq!(seq_bits, par_bits, "threads={threads}: final params drifted");
        assert_eq!(
            sequential.timeline.rows().len(),
            parallel.timeline.rows().len(),
            "threads={threads}: timeline gating drifted"
        );
        for (x, y) in sequential.timeline.rows().iter().zip(parallel.timeline.rows()) {
            assert_eq!((x.round, x.device, x.batch), (y.round, y.device, y.batch));
        }
        let (la, lb) = (sequential.logs.rounds(), parallel.logs.rounds());
        assert_eq!(la.len(), lb.len());
        for (x, y) in la.iter().zip(lb) {
            assert_eq!(x.global_batch, y.global_batch, "round {}", x.round);
            assert_eq!(x.committed_devices, y.committed_devices, "round {}", x.round);
            assert_eq!(
                x.wall_clock_s.to_bits(),
                y.wall_clock_s.to_bits(),
                "round {}",
                x.round
            );
        }
    }
}

#[test]
fn timeline_rows_are_gated_to_sampled_participants() {
    let cfg = sampled_cfg(1);
    let out = Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10)))
        .unwrap()
        .run()
        .unwrap();
    // k=3 of 8: at most 3 rows per round ever reach the timeline
    let rounds = out.logs.rounds().len();
    assert!(rounds > 0);
    assert!(
        out.timeline.rows().len() <= 3 * rounds,
        "timeline must be O(sampled), got {} rows over {rounds} rounds",
        out.timeline.rows().len()
    );
    for row in out.timeline.rows() {
        assert!(row.device < 8);
    }
    // the sampled set matches the sampler's own pure draw
    let mut sampler = FleetSampler::new("3".parse().unwrap(), 8, 13);
    for r in 0..rounds {
        let drawn = sampler.draw(r);
        for row in out.timeline.rows().iter().filter(|row| row.round == r) {
            assert!(
                drawn.contains(&row.device),
                "round {r}: device {} logged but not drawn {drawn:?}",
                row.device
            );
        }
    }
}

#[test]
fn two_tier_aggregation_is_bitwise_identical_to_flat_for_one_round() {
    // Gateway blocks are contiguous in device order, so hierarchical
    // aggregation must produce the *bit-identical* model the flat fold
    // does. Tier pricing moves the virtual clock, which cascades into
    // later rounds' stream state — so the equality is asserted on one
    // round from identical initial state, which is exactly where the
    // fold happens.
    let mk = |tiers: &str| {
        let cfg = ExperimentConfig::builder("mlp_c10")
            .devices(8)
            .rounds(4)
            .seed(21)
            .preset(StreamPreset::S1)
            .rate_jitter(0.2)
            .tiers(tiers.parse().unwrap())
            .worker_threads(1)
            .build()
            .unwrap();
        RoundEngine::new(&cfg, Box::new(MockBackend::new(96, 10))).unwrap()
    };
    for gateways in ["gateways:2", "gateways:4", "gateways:8"] {
        let mut flat = mk("flat");
        let mut tiered = mk(gateways);
        flat.round().unwrap();
        tiered.round().unwrap();
        let a: Vec<u32> = flat.params().iter().map(|p| p.to_bits()).collect();
        let b: Vec<u32> = tiered.params().iter().map(|p| p.to_bits()).collect();
        assert_eq!(a, b, "{gateways}: hierarchical fold != flat fold");
    }
    // and the degenerate single gateway prices both tiers but still
    // folds identically
    let mut flat = mk("flat");
    let mut one = mk("gateways:1");
    flat.round().unwrap();
    one.round().unwrap();
    assert_eq!(
        flat.params().iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
        one.params().iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
        "gateways:1 fold != flat fold"
    );
}

#[test]
fn tiered_pricing_moves_sync_time_but_counts_both_tiers() {
    use scadles::obs::Counter;
    let mut cfg = ExperimentConfig::builder("mlp_c10")
        .devices(8)
        .rounds(3)
        .seed(3)
        .preset(StreamPreset::S1)
        .tiers(TierPreset::gateways_preset(2))
        .worker_threads(1)
        .build()
        .unwrap();
    cfg.trace_capture = true;
    let mut t = Trainer::with_backend(&cfg, Box::new(MockBackend::new(96, 10))).unwrap();
    let out = t.run().unwrap();
    assert!(out.logs.rounds().iter().all(|r| r.wall_clock_s > 0.0));
    let reg = t.trace().expect("trace_capture installs the recorder").registry();
    assert!(
        reg.counter(Counter::TierDeviceSyncBits) > 0,
        "device tier bits must accumulate"
    );
    assert!(
        reg.counter(Counter::TierGatewaySyncBits) > 0,
        "gateway tier bits must accumulate"
    );
}
