//! The zero-alloc claim, enforced: once buffers are warm, the
//! compressed round's hot phases — radix threshold selection, masking
//! into the sparse view, the q8 wire encode/decode, error-feedback
//! absorption (f32 and quantized), weighted aggregation and the
//! momentum update, plus the tracing-off observability hooks
//! ([`NoopRecorder`] behind the engine's `dyn Recorder`) — perform
//! **no heap allocation at all**.
//!
//! A counting `#[global_allocator]` (toggled around the measured
//! window) wraps `System`; the pipeline below is exactly the per-device
//! + coordinator phase sequence the round engine runs over its
//! persistent buffers. One `#[test]` per file: integration-test
//! binaries are separate crates, so the allocator sees no foreign
//! threads, and nothing else can allocate inside the window.

// The workspace denies `unsafe_code`; a `GlobalAlloc` shim is the one
// legitimate exception — it is measurement-only, test-binary-only, and
// delegates every operation verbatim to `System`.
#![allow(unsafe_code)]

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use scadles::compress::{
    mask_stats_only, threshold_for_ratio_with, ErrorFeedback, QuantizedGrad, SelectScratch,
    SparseGrad,
};
use scadles::coordinator::{aggregate_rows_into, RowView};
use scadles::obs::{Counter, Gauge, NoopRecorder, Phase, Recorder, Track};
use scadles::rng::Pcg64;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

const D: usize = 8192;
const N: usize = 4;
const CR: f64 = 0.1;

fn fill_grad(rng: &mut Pcg64, out: &mut [f32]) {
    for v in out.iter_mut() {
        *v = rng.normal() as f32;
    }
}

#[test]
fn compressed_steady_state_phases_do_not_allocate() {
    let mut rng = Pcg64::new(42, 7);
    // persistent state, as DeviceWorker / Trainer own it
    let mut grads: Vec<Vec<f32>> = (0..N).map(|_| vec![0f32; D]).collect();
    let mut corrected: Vec<Vec<f32>> = (0..N).map(|_| vec![0f32; D]).collect();
    let mut efs: Vec<ErrorFeedback> = (0..N).map(|_| ErrorFeedback::new(D)).collect();
    // worst-case capacity up front: a magnitude tie at the threshold can
    // push nnz past ceil(CR·D), and this test must never flake on one
    let mut sparse: Vec<SparseGrad> = (0..N).map(|_| SparseGrad::with_capacity(D)).collect();
    let mut scratches: Vec<SelectScratch> =
        (0..N).map(|_| SelectScratch::with_capacity(D)).collect();
    // the q8 wire codec's level buffer, pre-sized like the sparse views
    let mut quants: Vec<QuantizedGrad> = (0..N)
        .map(|_| {
            let mut q = QuantizedGrad::default();
            q.qvals.reserve(D);
            q
        })
        .collect();
    let mut wire_rng = Pcg64::new(7, 0x317E);
    let mut agg = vec![0f32; D];
    let mut params = vec![0.1f32; D];
    let mut momentum = vec![0f32; D];
    let weights = [0.25f32; N];
    // tracing-off observability, exactly as the engine holds it: the
    // no-op recorder behind the trait object must cost zero heap —
    // every call below compiles to nothing
    let mut rec: Box<dyn Recorder> = Box::new(NoopRecorder);

    let mut pipeline = |count_window: bool| {
        // phase 6 stand-in: fresh gradients (outside the claim — the
        // backend owns the training step's output)
        for g in grads.iter_mut() {
            fill_grad(&mut rng, g);
        }
        if count_window {
            ALLOCS.store(0, Ordering::SeqCst);
            COUNTING.store(true, Ordering::SeqCst);
        }
        // phase 7: residual correction + threshold + mask → sparse view;
        // half the devices ship the f32 survivor wire, half the q8 wire
        // (stochastic encode + in-place dequant + quantized EF absorb) —
        // both variants must stay allocation-free once warm
        for i in 0..N {
            corrected[i].copy_from_slice(&grads[i]);
            efs[i].correct(&mut corrected[i]);
            let (_k, thresh) = threshold_for_ratio_with(&corrected[i], CR, &mut scratches[i]);
            let (_n2, _k2, nnz) = mask_stats_only(&corrected[i], thresh);
            sparse[i].fill_from_threshold(&corrected[i], thresh, nnz);
            if i < N / 2 {
                efs[i].absorb_sparse(&mut corrected[i], &sparse[i]);
            } else {
                quants[i].encode(&sparse[i], 8, &mut wire_rng);
                quants[i].decode_into(&mut sparse[i].val);
                efs[i].absorb_quantized(&mut corrected[i], &sparse[i]);
            }
        }
        // phase 8: O(Σ nnz) aggregation into the reused accumulator
        {
            let sparse = &sparse;
            aggregate_rows_into(&mut agg, &weights, |i| RowView::Sparse(&sparse[i]), 1);
        }
        // phase 9: in-place momentum update
        for ((p, m), g) in params.iter_mut().zip(momentum.iter_mut()).zip(&agg) {
            *m = 0.9 * *m + g;
            *p -= 0.05 * *m;
        }
        // the engine's per-round recorder traffic with tracing off:
        // gated behind `enabled()` on the hot path, and a no-op even
        // when called — neither side may allocate
        if rec.enabled() {
            rec.span(Track::Coordinator, Phase::Round, 0, 0.0, 1.0);
        }
        rec.span(Track::Device(0), Phase::Train, 0, 0.0, 1.0);
        rec.instant(Track::Coordinator, Phase::Plan, 0, 0.0);
        rec.add(Counter::Rounds, 1);
        rec.set_gauge(Gauge::RateEst, 64.0);
        rec.host_round_ns(0, 1);
        if count_window {
            COUNTING.store(false, Ordering::SeqCst);
            ALLOCS.load(Ordering::SeqCst)
        } else {
            0
        }
    };

    // warm-up: sparse vectors converge to their steady capacity
    for _ in 0..3 {
        pipeline(false);
    }
    // steady state: not a single heap allocation across five rounds
    for round in 0..5 {
        let allocs = pipeline(true);
        assert_eq!(
            allocs, 0,
            "round {round}: the compressed steady state allocated {allocs} time(s)"
        );
    }
}
