//! End-to-end tests over the real PJRT runtime + compiled artifacts.
//!
//! These exercise the actual L1/L2 HLO artifacts (`make artifacts` first)
//! and therefore need the *real* xla bindings — the offline `xla-stub`
//! build cannot execute them, so every test is `#[ignore]`d with a reason
//! (run with `cargo test -- --ignored` on a machine with the toolchain).
//! The `req!` guard additionally skips with a notice when the artifacts
//! directory is missing, so the suite stays usable mid-setup.

use std::path::PathBuf;
use std::sync::Arc;

use scadles::compress;
use scadles::config::{ExperimentConfig, StreamPreset, TrainMode};
use scadles::coordinator::{aggregate_native, Trainer};
use scadles::data::{EvalSet, Synthetic};
use scadles::rng::Pcg64;
use scadles::runtime::Runtime;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = std::env::var("SCADLES_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let p = PathBuf::from(dir);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {p:?}; run `make artifacts`");
        None
    }
}

fn runtime() -> Option<Arc<Runtime>> {
    // PJRT clients are thread-affine (Rc internally), so every test thread
    // builds its own runtime; executables compile lazily per test.
    artifacts_dir().map(|d| Arc::new(Runtime::load(d).unwrap()))
}

macro_rules! req {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

fn sample_batch(n: usize, ncls: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let data = Synthetic::standard(ncls, 42);
    let mut rng = Pcg64::new(seed, 0);
    let mut x = Vec::with_capacity(n * 3072);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = (i % ncls) as u32;
        x.extend(data.sample(label, rng.next_u64()));
        y.push(label as i32);
    }
    (x, y)
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn manifest_and_init_params_load() {
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let p = model.init_params().unwrap();
    assert_eq!(p.len(), model.param_count());
    assert!(p.iter().all(|v| v.is_finite()));
    assert!(p.iter().any(|&v| v != 0.0));
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn train_step_loss_starts_near_uniform() {
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let p = model.init_params().unwrap();
    let (x, y) = sample_batch(8, 10, 1);
    let out = model.train_step(&p, &x, &y, 8).unwrap();
    // CE at init ≈ ln(10) = 2.30 (He-init logits are small)
    assert!(
        (out.loss - 10f32.ln()).abs() < 1.0,
        "init loss {} vs ln(10)",
        out.loss
    );
    assert_eq!(out.grads.len(), model.param_count());
    let norm: f32 = out.grads.iter().map(|g| g * g).sum::<f32>().sqrt();
    assert!(norm > 1e-3 && norm.is_finite(), "grad norm {norm}");
    assert!(out.top5_correct >= out.top1_correct);
    assert!(out.top5_correct <= 8.0);
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn bucket_padding_is_neutral() {
    // the batch-bucket contract: same valid samples, different padding
    // bucket ⇒ identical loss/gradients (up to fp reduction order).
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let p = model.init_params().unwrap();
    let (x, y) = sample_batch(5, 10, 2);
    let a = model.train_step(&p, &x, &y, 8).unwrap();
    let b = model.train_step(&p, &x, &y, 16).unwrap();
    assert!((a.loss - b.loss).abs() < 1e-5, "{} vs {}", a.loss, b.loss);
    let max_dg = a
        .grads
        .iter()
        .zip(&b.grads)
        .map(|(u, v)| (u - v).abs())
        .fold(0f32, f32::max);
    assert!(max_dg < 1e-5, "max grad delta {max_dg}");
    assert_eq!(a.top1_correct, b.top1_correct);
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn train_step_is_deterministic() {
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let p = model.init_params().unwrap();
    let (x, y) = sample_batch(8, 10, 3);
    let a = model.train_step(&p, &x, &y, 8).unwrap();
    let b = model.train_step(&p, &x, &y, 8).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.grads, b.grads);
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn update_artifact_matches_native_momentum() {
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let d = model.param_count();
    let meta = model.meta().clone();
    let mut rng = Pcg64::new(9, 0);
    let mut params: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.01).collect();
    let mut mom: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.001).collect();
    let grad: Vec<f32> = (0..d).map(|_| rng.normal() as f32 * 0.1).collect();
    let (p0, m0) = (params.clone(), mom.clone());
    model.update(&mut params, &mut mom, &grad, 0.05).unwrap();
    for i in (0..d).step_by(997) {
        let g = grad[i] + meta.weight_decay * p0[i];
        let m_new = meta.momentum * m0[i] + g;
        let p_new = p0[i] - 0.05 * m_new;
        assert!((mom[i] - m_new).abs() < 1e-5, "mom[{i}]");
        assert!((params[i] - p_new).abs() < 1e-5, "param[{i}]");
    }
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn wagg_artifact_matches_native() {
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let d = model.param_count();
    let n = 4;
    let mut rng = Pcg64::new(11, 0);
    let grads: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let weights = vec![0.4f32, 0.3, 0.2, 0.1];
    let kernel = model.weighted_aggregate(&grads, &weights).unwrap();
    let native = aggregate_native(&grads, &weights, d);
    let max_d = kernel
        .iter()
        .zip(&native)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_d < 1e-4, "max wagg delta {max_d}");
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn topk_artifact_matches_native() {
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let d = model.param_count();
    let mut rng = Pcg64::new(13, 0);
    let g: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let (_k, thresh) = compress::threshold_for_ratio(&g, 0.1);
    let out = model.topk_mask_stats(&g, thresh).unwrap();
    let mut native = g.clone();
    let (n2, k2, nnz) = compress::mask_stats_native(&mut native, thresh);
    assert_eq!(out.masked, native);
    assert!((out.norm2 as f64 - n2).abs() / n2 < 1e-4);
    assert!((out.knorm2 as f64 - k2).abs() / k2 < 1e-4);
    assert_eq!(out.nnz as usize, nnz);
    // CR=0.1 keeps ~10%
    let frac = out.nnz as f64 / d as f64;
    assert!((frac - 0.1).abs() < 0.01, "kept fraction {frac}");
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn eval_step_counts_bounded() {
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let p = model.init_params().unwrap();
    let data = Synthetic::standard(10, 42);
    let ev = EvalSet::new(&data, 4);
    let mut total = 0f32;
    for (x, y) in ev.chunks(model.meta().eval_bucket) {
        let out = model.eval_step(&p, x, y).unwrap();
        assert!(out.top1_correct <= y.len() as f32);
        assert!(out.top5_correct <= y.len() as f32);
        assert!(out.top1_correct <= out.top5_correct);
        total += out.top5_correct;
    }
    assert!(total <= 40.0);
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn sgd_on_artifacts_reduces_loss() {
    // ten full train+update cycles through PJRT must overfit one batch
    let rt = req!(runtime());
    let model = rt.model("mlp_c10").unwrap();
    let mut p = model.init_params().unwrap();
    let mut m = vec![0f32; model.param_count()];
    let (x, y) = sample_batch(16, 10, 5);
    let l0 = model.train_step(&p, &x, &y, 16).unwrap().loss;
    for _ in 0..10 {
        let out = model.train_step(&p, &x, &y, 16).unwrap();
        model.update(&mut p, &mut m, &out.grads, 0.1).unwrap();
    }
    let l1 = model.train_step(&p, &x, &y, 16).unwrap().loss;
    assert!(l1 < l0 * 0.5, "loss {l0} -> {l1}");
}

#[test]
#[ignore = "requires compiled PJRT artifacts and the real xla bindings (run `make artifacts`, swap xla-stub), absent in CI"]
fn full_trainer_short_run_all_models() {
    let dir = req!(artifacts_dir());
    for model in ["mlp_c10", "resnet_tiny_c10"] {
        let cfg = ExperimentConfig::builder(model)
            .artifacts_dir(dir.clone())
            .devices(2)
            .rounds(3)
            .preset(StreamPreset::S1)
            .mode(TrainMode::Scadles)
            .eval_every(2)
            .build()
            .unwrap();
        let mut t = Trainer::from_config(&cfg).unwrap();
        let out = t.run().unwrap();
        assert_eq!(out.logs.rounds().len(), 3, "{model}");
        assert!(out.report.final_train_loss.is_finite(), "{model}");
        assert!(out.report.wall_clock_s > 0.0, "{model}");
    }
}
