//! Cross-module integration tests over the mock backend.
//!
//! These validate the *system* behaviours the paper claims, end-to-end
//! through the coordinator, stream substrate, buffer policies, compression
//! and injection — without needing compiled artifacts (see runtime_e2e.rs
//! for the PJRT-backed equivalents).

use scadles::buffer::BufferPolicy;
use scadles::config::{
    CompressionConfig, ExperimentConfig, InjectionConfig, StreamPreset, TrainMode,
};
use scadles::coordinator::{MockBackend, Trainer, TrainerOutput};
use scadles::data::LabelMap;
use scadles::harness::{HarnessOpts, EXPERIMENTS};

fn run(cfg: &ExperimentConfig) -> TrainerOutput {
    Trainer::with_backend(cfg, Box::new(MockBackend::new(64, 10)))
        .unwrap()
        .run()
        .unwrap()
}

fn base(mode: TrainMode, preset: StreamPreset) -> ExperimentConfig {
    ExperimentConfig::builder("mlp_c10")
        .devices(8)
        .rounds(25)
        .preset(preset)
        .mode(mode)
        .eval_every(5)
        .build()
        .unwrap()
}

// ---------------------------------------------------------------------------
// paper claim: ScaDLES avoids straggler waits → faster wall-clock (Fig. 7)
// ---------------------------------------------------------------------------

#[test]
fn scadles_beats_ddl_training_throughput_on_every_preset() {
    // the paper's speedup is time-to-accuracy; its mechanical driver is
    // samples trained per virtual second (no straggler waits + stream-sized
    // batches), which is robust to the mock's convergence model.
    for preset in StreamPreset::all() {
        let s = run(&base(TrainMode::Scadles, preset));
        let d = run(&base(TrainMode::Ddl, preset));
        let tput = |o: &TrainerOutput| {
            let samples: usize = o.logs.rounds().iter().map(|r| r.global_batch).sum();
            samples as f64 / o.report.wall_clock_s
        };
        let (st, dt) = (tput(&s), tput(&d));
        assert!(
            st > dt * 1.1,
            "{}: scadles {st:.0} ≤ ddl {dt:.0} samples/s",
            preset.name()
        );
        // S1 (heterogeneous, low volume): stragglers also hurt DDL's raw
        // wall clock for the same round count.
        if preset == StreamPreset::S1 {
            assert!(
                d.report.wall_clock_s > s.report.wall_clock_s,
                "S1: ddl {:.0}s vs scadles {:.0}s",
                d.report.wall_clock_s,
                s.report.wall_clock_s
            );
        }
    }
}

// ---------------------------------------------------------------------------
// paper claim: ScaDLES buffers less than DDL under persistence (Fig. 8)
// ---------------------------------------------------------------------------

#[test]
fn scadles_buffers_grow_slower_than_ddl_on_high_volume_streams() {
    // equal round counts run for different virtual horizons, so compare the
    // steady-state buffer growth RATE (samples queued per virtual second):
    // ScaDLES consumes ~ΣS per round vs DDL's fixed 64·n.
    let growth_rate = |o: &TrainerOutput| {
        let logs = o.logs.rounds();
        let (a, b) = (&logs[4], logs.last().unwrap());
        (b.buffered_samples as f64 - a.buffered_samples as f64)
            / (b.wall_clock_s - a.wall_clock_s)
    };
    for preset in [StreamPreset::S2, StreamPreset::S2Prime] {
        let s = run(&base(TrainMode::Scadles, preset));
        let d = run(&base(TrainMode::Ddl, preset));
        let (sr, dr) = (growth_rate(&s), growth_rate(&d));
        assert!(
            sr < dr * 0.8,
            "{}: scadles grows {sr:.0}/s vs ddl {dr:.0}/s",
            preset.name()
        );
    }
}

// ---------------------------------------------------------------------------
// paper claim: truncation gives orders-of-magnitude buffer cuts (Table IV)
// ---------------------------------------------------------------------------

#[test]
fn truncation_reduction_grows_with_rounds() {
    let mut cfg = base(TrainMode::Scadles, StreamPreset::S2);
    cfg.rounds = 40;
    let pers = run(&cfg);
    cfg.buffer_policy = BufferPolicy::Truncation;
    let trunc = run(&cfg);
    let reduction =
        pers.report.buffer.final_samples as f64 / trunc.report.buffer.final_samples.max(1) as f64;
    assert!(reduction > 5.0, "reduction only {reduction:.1}x");
    // truncation's buffer is O(ΣS): bounded by ~one second of cluster stream
    let sum_rates: f64 = trunc.rates.iter().sum();
    assert!(
        (trunc.report.buffer.final_samples as f64) < sum_rates * 3.0,
        "truncation buffer {} vs ΣS {}",
        trunc.report.buffer.final_samples,
        sum_rates
    );
}

// ---------------------------------------------------------------------------
// paper claim: adaptive compression cuts volume, δ controls CNC (Table V)
// ---------------------------------------------------------------------------

#[test]
fn cnc_monotone_in_delta() {
    let mut cncs = Vec::new();
    for delta in [0.05, 0.3, 0.9] {
        let mut cfg = base(TrainMode::Scadles, StreamPreset::S1Prime);
        cfg.compression = Some(CompressionConfig::new(0.1, delta));
        let out = run(&cfg);
        cncs.push(out.report.cnc_ratio);
    }
    assert!(
        cncs[0] <= cncs[1] + 1e-9 && cncs[1] <= cncs[2] + 1e-9,
        "CNC not monotone in delta: {cncs:?}"
    );
    assert!(cncs[2] > 0.5, "permissive delta should mostly compress: {cncs:?}");
}

#[test]
fn compression_cuts_floats_proportionally_to_cr() {
    let dense = run(&base(TrainMode::Scadles, StreamPreset::S1Prime))
        .report
        .total_floats_sent;
    let mut cfg = base(TrainMode::Scadles, StreamPreset::S1Prime);
    cfg.compression = Some(CompressionConfig::new(0.1, 10.0)); // always compress
    let sparse = run(&cfg).report.total_floats_sent;
    let ratio = sparse as f64 / dense as f64;
    assert!(ratio < 0.15, "floats ratio {ratio} (CR=0.1)");
}

// ---------------------------------------------------------------------------
// paper claim: injection fixes non-IID convergence (Fig. 9) & costs KB (Fig. 10)
// ---------------------------------------------------------------------------

#[test]
fn injection_improves_noniid_convergence_on_mock() {
    let mk = |inj: Option<InjectionConfig>| {
        let mut cfg = base(TrainMode::Scadles, StreamPreset::S1);
        cfg.label_map = LabelMap::NonIid { labels_per_device: 1 };
        cfg.rounds = 30;
        cfg.injection = inj;
        run(&cfg)
    };
    let without = mk(None);
    let with = mk(Some(InjectionConfig::new(0.5, 0.5)));
    // mock backend can't model label skew directly, but injection must not
    // hurt and must move bytes; real-model validation lives in the harness.
    assert!(with.report.injection_bytes > 0);
    assert_eq!(without.report.injection_bytes, 0);
    assert!(with.report.final_train_loss.is_finite());
}

#[test]
fn injection_overhead_scales_with_alpha_beta() {
    let mk = |a: f64, b: f64| {
        let mut cfg = base(TrainMode::Scadles, StreamPreset::S1);
        cfg.label_map = LabelMap::NonIid { labels_per_device: 1 };
        cfg.injection = Some(InjectionConfig::new(a, b));
        run(&cfg).report.injection_bytes
    };
    let small = mk(0.05, 0.05);
    let large = mk(0.5, 0.5);
    assert!(large > small * 4, "large {large} vs small {small}");
}

// ---------------------------------------------------------------------------
// failure injection / resilience
// ---------------------------------------------------------------------------

#[test]
fn missing_artifacts_surface_clean_error() {
    let cfg = ExperimentConfig::builder("mlp_c10")
        .artifacts_dir("/nonexistent/path")
        .build()
        .unwrap();
    let err = match Trainer::from_config(&cfg) {
        Ok(_) => panic!("expected missing-artifacts error"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("manifest.json") || err.contains("artifacts"), "{err}");
}

#[test]
fn unknown_experiment_id_rejected() {
    let err = scadles::harness::run("fig99", &HarnessOpts::default())
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown experiment"));
    assert!(EXPERIMENTS.len() >= 17);
}

#[test]
fn rate_jitter_keeps_training_stable() {
    let mut cfg = base(TrainMode::Scadles, StreamPreset::S1);
    cfg.rate_jitter = 0.5; // violent intra-device heterogeneity
    let out = run(&cfg);
    assert!(out.report.final_train_loss.is_finite());
    assert_eq!(out.logs.rounds().len(), 25);
    // batches still respect bounds every round
    for log in out.logs.rounds() {
        assert!(log.global_batch >= 8 * 1);
        assert!(log.global_batch <= 256 * 8);
    }
}

#[test]
fn single_device_cluster_trains() {
    let mut cfg = base(TrainMode::Scadles, StreamPreset::S1Prime);
    cfg.devices = 1;
    let out = run(&cfg);
    assert!(out.report.final_train_loss < 1.0);
}

// ---------------------------------------------------------------------------
// determinism across the whole stack
// ---------------------------------------------------------------------------

#[test]
fn identical_configs_reproduce_bitwise_reports() {
    let cfg = base(TrainMode::Scadles, StreamPreset::S2Prime);
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.report.wall_clock_s, b.report.wall_clock_s);
    assert_eq!(a.report.total_floats_sent, b.report.total_floats_sent);
    assert_eq!(a.report.buffer.final_samples, b.report.buffer.final_samples);
    assert_eq!(a.rates, b.rates);
}
