//! Deterministic in-process cluster simulation: coordinator + N device
//! automata over the virtual-tick transport, with the full fault-preset
//! axis. One process, one thread of control-plane logic, an entire
//! lossy cluster.
//!
//! Contract under test (the runtime's acceptance criteria):
//! - the state machine walks STANDBY → ROUND → FINISHED and every round
//!   eventually commits, at every loss rate;
//! - round progression is strict (one committed round per `step()`);
//! - the trained model is **bitwise identical** across loss rates
//!   {0, 0.1, 0.3}, duplication, and worker-pool widths {1, 4, 8} —
//!   transport faults are absorbed entirely by the control plane.

use scadles::config::{ExperimentConfig, NetPreset, StreamPreset};
use scadles::coordinator::{CoordinatorRuntime, MockBackend, RuntimeState, TrainerOutput};

const DEVICES: usize = 6;
const ROUNDS: usize = 10;

fn cfg(net: NetPreset, threads: usize, seed: u64) -> ExperimentConfig {
    ExperimentConfig::builder("mlp_c10")
        .devices(DEVICES)
        .rounds(ROUNDS)
        .seed(seed)
        .preset(StreamPreset::S1)
        .eval_every(5)
        .worker_threads(threads)
        .net(net)
        .build()
        .unwrap()
}

fn runtime(net: NetPreset, threads: usize, seed: u64) -> CoordinatorRuntime {
    CoordinatorRuntime::new(&cfg(net, threads, seed), Box::new(MockBackend::new(96, 10)))
        .unwrap()
}

/// Run to completion, returning the output and the final parameter bits.
fn simulate(net: NetPreset, threads: usize, seed: u64) -> (TrainerOutput, Vec<u32>) {
    let mut rt = runtime(net, threads, seed);
    let out = rt.run().unwrap();
    assert_eq!(rt.state(), RuntimeState::Finished, "net={net:?} threads={threads}");
    let bits = rt.engine().params().iter().map(|p| p.to_bits()).collect();
    (out, bits)
}

/// The loss-rate axis: 0 (drops off, delays still on — the transport
/// machinery runs but never loses), 0.1 and 0.3; plus a duplication
/// preset (the receiver must be idempotent).
fn fault_axis() -> Vec<(&'static str, NetPreset)> {
    vec![
        ("loss-0", NetPreset::lossy(0.0, 0.5, 2)),
        ("loss-0.1", NetPreset::lossy(0.1, 0.5, 3)),
        ("loss-0.3", NetPreset::lossy(0.3, 0.5, 3)),
        ("dup-0.3", NetPreset::dup(0.3)),
    ]
}

#[test]
fn every_loss_rate_converges_to_the_lossless_bits_at_every_width() {
    for seed in [7u64, 42] {
        let (lossless, reference) = simulate(NetPreset::None, 1, seed);
        assert!(lossless.report.final_train_loss.is_finite());
        for (name, net) in fault_axis() {
            for threads in [1usize, 4, 8] {
                let (out, bits) = simulate(net, threads, seed);
                assert_eq!(
                    bits, reference,
                    "{name} seed={seed} threads={threads}: model diverged from lossless"
                );
                assert_eq!(
                    out.report.final_train_loss.to_bits(),
                    lossless.report.final_train_loss.to_bits(),
                    "{name} seed={seed} threads={threads}: loss diverged"
                );
                // every round committed with a full attestation
                // (witnesses=0 → all live devices; nothing crashes here)
                assert_eq!(out.logs.rounds().len(), ROUNDS);
                for l in out.logs.rounds() {
                    assert_eq!(
                        l.witness_acks, DEVICES as u64,
                        "{name} seed={seed} threads={threads} round {}",
                        l.round
                    );
                }
            }
        }
    }
}

#[test]
fn rounds_progress_one_committed_round_per_step_under_heavy_loss() {
    let mut rt = runtime(NetPreset::lossy(0.3, 0.5, 3), 4, 42);
    assert_eq!(rt.state(), RuntimeState::Standby);
    for r in 0..ROUNDS {
        let log = rt.step().unwrap();
        assert_eq!(log.round, r, "strict round progression");
        assert_eq!(rt.engine().rounds_completed(), r + 1);
        let expected = if r + 1 < ROUNDS {
            RuntimeState::Round
        } else {
            RuntimeState::Finished
        };
        assert_eq!(rt.state(), expected, "after round {r}");
    }
    assert!(rt.step().is_err(), "a finished runtime must refuse to step");
    // heavy loss left real damage on the wire...
    let net = rt.net_counters().unwrap();
    assert!(net.dropped > 0, "drop 0.3 never dropped a send: {net:?}");
    // ...but nobody was ever evicted for it (heartbeats resend every
    // tick of the deadline window) and nothing needed a replay
    let out = rt.engine().finish();
    assert_eq!(out.resilience.heartbeat_misses, 0, "{:?}", out.resilience);
    assert_eq!(out.resilience.round_replays, 0, "{:?}", out.resilience);
}

#[test]
fn control_plane_ledger_is_pure_in_seed_device_round() {
    // The retransmit/ack tallies are themselves deterministic: two
    // simulations of the same (seed, preset) produce identical ledgers
    // and identical wire-damage counters, at different pool widths.
    let run = |threads: usize| {
        let mut rt = runtime(NetPreset::lossy(0.3, 0.5, 3), threads, 7);
        let out = rt.run().unwrap();
        (out.resilience, rt.net_counters().unwrap())
    };
    let (ledger, wire) = run(1);
    assert!(wire.dropped > 0 && wire.delayed > 0, "{wire:?}");
    for threads in [4usize, 8] {
        let (l, w) = run(threads);
        assert_eq!(l, ledger, "ledger drifted at width {threads}");
        assert_eq!(w, wire, "wire counters drifted at width {threads}");
    }
}
