//! Offline stub of the `xla` PJRT bindings.
//!
//! The scadles runtime (`rust/src/runtime/`) executes AOT-compiled HLO
//! artifacts through the PJRT CPU client of the real `xla` crate
//! (xla_extension bindings). That toolchain is unavailable in the offline
//! build sandbox, so this crate provides the exact API surface the
//! runtime calls against — types, signatures and error plumbing — with
//! every execution entry point returning a descriptive runtime error.
//!
//! Consequences:
//! * `cargo build` / `cargo test` / `cargo bench` work with no native
//!   XLA toolchain installed; everything artifact-free (the coordinator,
//!   stream substrate, compression, mock-backend training) is fully
//!   functional.
//! * Anything that actually needs compiled artifacts
//!   (`Trainer::from_config`, `repro info`, the PJRT benches, the
//!   `runtime_e2e` tests) fails fast at `PjRtClient::cpu()` /
//!   `HloModuleProto::from_text_file()` with an error explaining how to
//!   get the real substrate.
//! * All stub types are `Send + Sync`, matching the parallel round
//!   engine's requirement that a `Backend` be shareable across device
//!   workers. A real-bindings build must provide the same guarantee
//!   (e.g. one client per worker or an internally synchronized client).
//!
//! Swap the `xla = { path = "xla-stub" }` dependency in
//! `rust/Cargo.toml` for the real bindings to run compiled models.

use std::fmt;

/// Error type mirroring the real bindings' error enum closely enough for
/// `anyhow` interop (`std::error::Error + Send + Sync`).
#[derive(Debug)]
pub struct Error {
    message: String,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error {
        message: format!(
            "{what}: the XLA/PJRT substrate is not available in this build \
             (offline `xla-stub`). Install the real xla bindings and compile \
             artifacts with `make artifacts` to execute models."
        ),
    })
}

/// Element types the runtime marshals (`f32` data, `i32` labels).
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

/// Host-side tensor value. The stub carries no data: literals can be
/// constructed (cheaply) but never executed or read back.
#[derive(Debug, Clone, Default)]
pub struct Literal {
    _priv: (),
}

impl Literal {
    /// Build a rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _priv: () }
    }

    /// Build a rank-0 literal.
    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal { _priv: () }
    }

    /// Reinterpret with a new shape (element count must match).
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// First element of the buffer.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }

    /// Destructure a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// Destructure a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    /// Copy the raw buffer into `dst` (lengths must match).
    pub fn copy_raw_to<T: NativeType>(&self, _dst: &mut [T]) -> Result<()> {
        unavailable("Literal::copy_raw_to")
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (real bindings: protobuf parsed from text/binary).
#[derive(Debug)]
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Parse an HLO text file emitted by the AOT pipeline.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Device-resident result buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Transfer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Execute with one replica; outer vec is per-device, inner per-output.
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (CPU plugin in this repo).
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create the CPU client. Always errors in the stub — this is the
    /// single choke point that keeps artifact-dependent paths honest.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn stub_types_are_send_sync() {
        assert_send_sync::<Literal>();
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<PjRtBuffer>();
        assert_send_sync::<Error>();
    }

    #[test]
    fn execution_paths_error_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("xla-stub"), "{err}");
        assert!(err.contains("make artifacts"), "{err}");
        let lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.reshape(&[2, 1]).is_err());
    }

    #[test]
    fn literals_construct_cheaply() {
        let _ = Literal::scalar(0.5f32);
        let _ = Literal::vec1(&[1i32, 2, 3]);
        let c = XlaComputation::from_proto(&HloModuleProto { _priv: () });
        let _ = format!("{c:?}");
    }
}
