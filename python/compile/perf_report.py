"""§Perf L1/L2 report: HLO audit + Pallas kernel VMEM/MXU estimates.

Usage: (cd python && python -m compile.perf_report [--artifacts ../artifacts])

L1 (Pallas): interpret=True gives CPU-numpy timing only, so real-TPU
behaviour is *estimated from the BlockSpecs*: per-step VMEM footprint
(operand + output tiles, double-buffered), arithmetic intensity, and MXU
utilization for the matmul tiles. These are the numbers DESIGN.md
§Hardware-Adaptation commits to.

L2 (JAX graph): parses the lowered HLO text of each artifact and reports
op histograms — the audit that catches un-fused elementwise chains,
redundant transposes/recomputation, and oversized constants.
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from collections import Counter

from . import model as M
from .kernels import matmul as km
from .kernels import topk as kt
from .kernels import wagg as kw

MXU_DIM = 128  # TPU systolic array edge
VMEM_BYTES = 16 * 2**20  # ~16 MiB per core


def kernel_estimates():
    print("== L1 Pallas kernel estimates (TPU model; CPU runs interpret mode) ==")
    print(f"{'kernel':<22} {'tile':<18} {'VMEM/step':>12} {'arith int.':>12} {'MXU util':>10}")

    # matmul: (bm, bk) + (bk, bn) + (bm, bn) f32 tiles, double-buffered ins
    for (m, k, n) in [(64, 3072, 256), (256, 2048, 256), (64, 128, 10)]:
        bm = km._block(m, 128)
        bn = km._block(n, 128)
        bk = km._block(k, 512)
        vmem = 4 * (2 * (bm * bk + bk * bn) + bm * bn)  # dbl-buffered inputs
        flops = 2 * bm * bn * bk
        bytes_moved = 4 * (bm * bk + bk * bn)  # output stays resident
        ai = flops / bytes_moved
        util = min(bm, MXU_DIM) * min(bn, MXU_DIM) / (MXU_DIM * MXU_DIM)
        print(f"{'matmul %dx%dx%d' % (m,k,n):<22} {'(%d,%d)x(%d,%d)' % (bm,bk,bk,bn):<18} "
              f"{vmem/1024:>10.0f}KiB {ai:>11.1f} {util:>9.0%}")
        assert vmem < VMEM_BYTES, "tile exceeds VMEM"

    # wagg: (n, TILE_D) slab + (TILE_D,) out; VPU-bound
    for n in [16, 25]:
        td = kw._block(821_248, kw.TILE_D)  # padded dim (multiple of 4096)
        vmem = 4 * (2 * n * td + td + n)
        ai = (2 * n * td) / (4 * (n * td + td))  # ~0.5 flop/byte → VPU-bound
        print(f"{'wagg n=%d' % n:<22} {'(%d,%d)' % (n, td):<18} "
              f"{vmem/1024:>10.0f}KiB {ai:>11.2f} {'VPU':>10}")
        assert vmem < VMEM_BYTES

    # topk mask: (TILE_D,) slab in/out + 3 scalars
    td = kt._block(821_248, kt.TILE_D)  # padded dim
    vmem = 4 * (2 * 2 * td + 3)
    print(f"{'topk_mask':<22} {'(%d,)' % td:<18} {vmem/1024:>10.0f}KiB "
          f"{5/8:>11.2f} {'VPU':>10}")
    print(f"\nVMEM budget/core: {VMEM_BYTES//2**20} MiB — all kernels fit with "
          "double buffering; matmul output tile stays resident across the K loop.")


_OP_RE = re.compile(r"=\s+[a-z0-9\[\]{},: ]*?\b([a-z][a-z0-9-]*)\(")


def hlo_audit(artifacts: str):
    print("\n== L2 HLO audit (lowered artifacts) ==")
    rows = []
    for name in sorted(os.listdir(artifacts)):
        if not name.endswith(".hlo.txt"):
            continue
        text = open(os.path.join(artifacts, name)).read()
        ops = Counter()
        for line in text.splitlines():
            mm = _OP_RE.search(line)
            if mm:
                ops[mm.group(1)] += 1
        total = sum(ops.values())
        hot = ", ".join(f"{op}:{c}" for op, c in ops.most_common(4))
        rows.append((name, total, ops, hot, len(text)))
    print(f"{'artifact':<42} {'ops':>6} {'KB':>7}  top ops")
    for name, total, ops, hot, size in rows:
        print(f"{name:<42} {total:>6} {size/1024:>7.0f}  {hot}")

    # audit checks
    print("\naudit checks:")
    issues = 0
    for name, total, ops, _, _ in rows:
        if "train_step" in name and "resnet" in name:
            # expect fwd + dgrad + wgrad ≈ 3× the 15 forward convs;
            # anything above 4× means XLA re-materialized activations
            convs = ops.get("convolution", 0)
            if convs > 4 * 15:
                print(f"  WARN {name}: {convs} convolutions (recompute?)")
                issues += 1
        if ops.get("transpose", 0) > ops.get("dot", 0) * 3 + 20:
            print(f"  WARN {name}: transpose-heavy ({ops.get('transpose')})")
            issues += 1
        if "while" in ops and "update" in name:
            print(f"  WARN {name}: loop in elementwise update")
            issues += 1
    if not issues:
        print("  none — no recomputation, no loop-carried updates, "
              "transposes proportional to dots")


def param_flops():
    print("\n== model fwd+bwd FLOPs/sample (paper-scale context) ==")
    for name in ["mlp_c10", "resnet_tiny_c10", "vgg_tiny_c100"]:
        d = M.param_count(name)
        # dense-equivalent: fwd ≈ 2·d, bwd ≈ 4·d (rough, conv-dominated
        # models are higher; good enough for roofline ratios)
        print(f"{name:<20} d={d:>9,}  ~{6*d/1e6:.1f} MFLOP/sample (dense-equiv)")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args(argv)
    kernel_estimates()
    if os.path.isdir(args.artifacts):
        hlo_audit(args.artifacts)
    else:
        print(f"(no artifacts at {args.artifacts}; HLO audit skipped)")
    param_flops()
    return 0


if __name__ == "__main__":
    sys.exit(main())
