"""L1 Pallas kernel: Top-k magnitude mask + adaptive-compression statistics.

ScaDLES's adaptive compression rule (paper §IV) needs, per iteration and
per gradient vector g:

    send(Topk(g))  if  | |g|^2 - |Topk(g)|^2 | / |g|^2  <= delta
    send(g)        otherwise

Given the k-th magnitude threshold (computed O(d) in the Rust coordinator
with select_nth — on real TPU this would be a two-pass histogram kernel),
this kernel produces in ONE streaming pass over g:

    masked  [d] : g with sub-threshold entries zeroed (the Topk(g) tensor)
    norm2   [1] : |g|^2
    knorm2  [1] : |Topk(g)|^2
    nnz     [1] : number of surviving elements

TPU mapping: `(TILE_D,)` slabs HBM→VMEM, elementwise compare + multiply on
the VPU, with the three scalars accumulated across grid steps in SMEM-like
(1,) output refs (sequential grid ⇒ safe accumulation). interpret=True for
CPU-PJRT (see matmul.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 8192


def _block(dim: int, target: int) -> int:
    target = min(dim, target)
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _topk_kernel(g_ref, t_ref, m_ref, n2_ref, k2_ref, nnz_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        n2_ref[...] = jnp.zeros_like(n2_ref)
        k2_ref[...] = jnp.zeros_like(k2_ref)
        nnz_ref[...] = jnp.zeros_like(nnz_ref)

    g = g_ref[...]
    thresh = t_ref[0]
    keep = jnp.abs(g) >= thresh
    masked = jnp.where(keep, g, 0.0)
    m_ref[...] = masked
    n2_ref[...] += jnp.sum(g * g, keepdims=True)
    k2_ref[...] += jnp.sum(masked * masked, keepdims=True)
    nnz_ref[...] += jnp.sum(keep.astype(jnp.float32), keepdims=True)


def topk_mask_stats(g: jax.Array, thresh: jax.Array, *, tile_d: int = TILE_D):
    """Apply magnitude threshold and compute compression statistics.

    g:      [d] flat gradient
    thresh: [1] magnitude threshold (k-th largest |g|)
    returns (masked [d], norm2 [1], knorm2 [1], nnz [1])
    """
    (d,) = g.shape
    bd = _block(d, tile_d)
    return pl.pallas_call(
        _topk_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(g, thresh)
