"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package has an exact pure-`jax.numpy`
counterpart here. The pytest suite (python/tests/test_kernels.py) sweeps
shapes/dtypes with hypothesis and asserts `allclose(kernel, ref)` — this is
the CORE correctness signal for Layer 1; the AOT artifacts embed the Pallas
versions, so if these match, the Rust runtime computes the same numbers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference for kernels.matmul.matmul: plain f32 matmul."""
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def wagg_ref(grads: jax.Array, weights: jax.Array) -> jax.Array:
    """Reference for kernels.wagg.weighted_aggregate.

    grads:   [n, d]  per-device flat gradients
    weights: [n]     aggregation weights r_i (ScaDLES Eqn. 4a; sum to 1
                     for active devices, 0 for padded slots)
    returns: [d]     g_tilde = sum_i r_i * g_i   (Eqn. 4b)
    """
    return jnp.einsum("nd,n->d", grads.astype(jnp.float32), weights.astype(jnp.float32))


def topk_mask_ref(g: jax.Array, thresh: jax.Array):
    """Reference for kernels.topk.topk_mask_stats.

    Applies a magnitude threshold (|g_j| >= thresh keeps the element) and
    returns the statistics ScaDLES's adaptive-compression rule needs:

      masked : g with sub-threshold entries zeroed
      norm2  : |g|^2           (uncompressed energy)
      knorm2 : |Topk(g)|^2     (compressed energy)
      nnz    : number of kept elements (as f32)

    The k-th magnitude selection itself happens in the Rust coordinator
    (O(d) select_nth); the kernel only applies the resulting threshold so
    it stays a single streaming pass.
    """
    g = g.astype(jnp.float32)
    keep = jnp.abs(g) >= thresh
    masked = jnp.where(keep, g, 0.0)
    norm2 = jnp.sum(g * g)
    knorm2 = jnp.sum(masked * masked)
    nnz = jnp.sum(keep.astype(jnp.float32))
    return masked, norm2, knorm2, nnz


def sgd_momentum_ref(params, mom, grad, lr, momentum, weight_decay):
    """Reference for the fused momentum-SGD update (PyTorch semantics).

    v' = mu * v + (g + wd * w);  w' = w - lr * v'
    """
    params = params.astype(jnp.float32)
    g = grad.astype(jnp.float32) + weight_decay * params
    mom_new = momentum * mom.astype(jnp.float32) + g
    return params - lr * mom_new, mom_new
