"""L1 Pallas kernel: ScaDLES weighted gradient aggregation (Eqn. 4b).

Computes g_tilde = sum_i r_i * g_i over the device axis. This is the
bandwidth-bound hot-spot of every synchronization round: n flat gradient
vectors of length d (d = model parameter count) are reduced with per-device
weights r_i = S_i / sum_j S_j (Eqn. 4a).

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel streams
`(n, TILE_D)` slabs HBM→VMEM so the device-axis reduction happens entirely
in VMEM — one pass over the n*d gradient matrix, VPU-bound, no MXU needed.
The weight vector is tiny and pinned for the whole grid. `interpret=True`
for CPU-PJRT execution (see matmul.py for why).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default tile along the parameter axis. 16 devices x 4096 f32 = 256 KiB
#: per slab — comfortably inside a 16 MiB VMEM with double-buffering.
TILE_D = 4096


def _block(dim: int, target: int) -> int:
    target = min(dim, target)
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _wagg_kernel(g_ref, r_ref, o_ref):
    """One grid step: o[tile] = r @ g[:, tile] (device-axis reduction)."""
    # g_ref: [n, bd], r_ref: [n], o_ref: [bd]
    o_ref[...] = jnp.einsum(
        "nd,n->d", g_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )


def weighted_aggregate(grads: jax.Array, weights: jax.Array, *, tile_d: int = TILE_D) -> jax.Array:
    """[n, d] gradients + [n] weights -> [d] aggregated gradient."""
    n, d = grads.shape
    assert weights.shape == (n,), f"weights {weights.shape} != ({n},)"
    bd = _block(d, tile_d)
    return pl.pallas_call(
        _wagg_kernel,
        grid=(d // bd,),
        in_specs=[
            pl.BlockSpec((n, bd), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(grads, weights)
