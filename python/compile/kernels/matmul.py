"""L1 Pallas kernel: tiled matmul with f32 VMEM accumulator.

This is the dense-head hot-spot of every model in the zoo. The tiling is
written the way a TPU Pallas kernel would be: `(bm, bn)` output tiles
matching the 128x128 MXU systolic array where the operands allow it, the
K dimension streamed through VMEM in `bk` slabs, and a float32 scratch
accumulator that only spills to the output ref on the final K step.

Lowered with ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode traces the kernel into plain HLO
(same numerics, same schedule structure). DESIGN.md §Hardware-Adaptation
records the VMEM/MXU estimate for the real-TPU variant.

Differentiability: ``pallas_call`` has no autodiff rule, so ``matmul`` is a
``jax.custom_vjp`` whose backward pass reuses the same kernel
(dx = dy @ w.T, dw = x.T @ dy) — the whole fwd/bwd graph stays on the
Pallas path and lowers into one HLO artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(dim: int, target: int) -> int:
    """Largest divisor of `dim` that is <= target (keeps the grid exact)."""
    target = min(dim, target)
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (i, j, k) grid step: o += x_tile @ w_tile.

    K is the innermost grid axis, so the (i, j) output block stays resident
    in VMEM across the whole K loop — the f32 output block doubles as the
    MXU accumulator (zeroed on the first K step).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul_pallas(x: jax.Array, w: jax.Array, *, bm=128, bn=128, bk=512) -> jax.Array:
    """[m, k] @ [k, n] -> [m, n] via the tiled Pallas kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul inner dims mismatch: {x.shape} @ {w.shape}"
    bm, bn, bk = _block(m, bm), _block(n, bn), _block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable Pallas matmul (see module docstring)."""
    return matmul_pallas(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas(x, w), (x, w)


def _matmul_bwd(res, dy):
    x, w = res
    dx = matmul_pallas(dy, w.T)
    dw = matmul_pallas(x.T, dy)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)
