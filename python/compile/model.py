"""L2: JAX model zoo for the ScaDLES reproduction (build-time only).

Defines the forward/backward computation graphs that `aot.py` lowers to
HLO-text artifacts executed by the Rust coordinator. Three families:

  * ``mlp``         — 3072→256→128→C, all Pallas-matmul dense layers.
                      Fast; used by the test suite and quickstart.
  * ``resnet_tiny`` — CIFAR-style residual network (proxy for the paper's
                      ResNet152; same optimizer family: momentum 0.9,
                      weight-decay 1e-4).
  * ``vgg_tiny``    — plain conv stack + big dense head (proxy for VGG19;
                      momentum 0.9, weight-decay 5e-4). The oversized dense
                      head reproduces VGG's parameter skew, which drives
                      the paper's communication results.

Every dense layer runs through the L1 Pallas ``matmul`` kernel so the
kernels lower into the same HLO artifacts the Rust runtime loads.

Parameter handling: the Rust boundary sees ONE flat f32 vector. ``spec()``
gives the ordered (name, shape) layout; ``flatten``/``unflatten`` convert.
All train/eval entry points take padded batches plus a ``mask`` so the
fixed-shape artifacts serve any batch ≤ bucket (DESIGN.md §1).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax, random

from .kernels.matmul import matmul

# ---------------------------------------------------------------------------
# Model registry
# ---------------------------------------------------------------------------

#: name -> (family, num_classes, momentum, weight_decay)
MODELS: Dict[str, Tuple[str, int, float, float]] = {
    "mlp_c10": ("mlp", 10, 0.9, 1e-4),
    "mlp_c100": ("mlp", 100, 0.9, 1e-4),
    "resnet_tiny_c10": ("resnet", 10, 0.9, 1e-4),
    "resnet_tiny_c100": ("resnet", 100, 0.9, 1e-4),
    "vgg_tiny_c10": ("vgg", 10, 0.9, 5e-4),
    "vgg_tiny_c100": ("vgg", 100, 0.9, 5e-4),
}

IMG = (32, 32, 3)  # CIFAR-shaped inputs (NHWC)

_RESNET_STAGES = [(16, 2, 1), (32, 2, 2), (64, 2, 2)]  # (channels, blocks, stride)
_VGG_CFG = [32, 32, "M", 64, 64, "M", 128, 128, "M"]
_GN_GROUPS = 8


def spec(model: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list defining the flat parameter layout."""
    family, ncls, _, _ = MODELS[model]
    out: List[Tuple[str, Tuple[int, ...]]] = []
    if family == "mlp":
        out += [("fc1.w", (3072, 256)), ("fc1.b", (256,)),
                ("fc2.w", (256, 128)), ("fc2.b", (128,)),
                ("head.w", (128, ncls)), ("head.b", (ncls,))]
    elif family == "resnet":
        cin = 3
        out.append(("stem.w", (3, 3, cin, 16)))
        cin = 16
        for si, (ch, blocks, _stride) in enumerate(_RESNET_STAGES):
            for bi in range(blocks):
                pre = f"s{si}.b{bi}"
                out += [(f"{pre}.gn1.g", (cin,)), (f"{pre}.gn1.b", (cin,)),
                        (f"{pre}.conv1.w", (3, 3, cin, ch)),
                        (f"{pre}.gn2.g", (ch,)), (f"{pre}.gn2.b", (ch,)),
                        (f"{pre}.conv2.w", (3, 3, ch, ch))]
                if cin != ch:
                    out.append((f"{pre}.proj.w", (1, 1, cin, ch)))
                cin = ch
        out += [("final.gn.g", (cin,)), ("final.gn.b", (cin,)),
                ("head.w", (cin, ncls)), ("head.b", (ncls,))]
    elif family == "vgg":
        cin = 3
        li = 0
        for v in _VGG_CFG:
            if v == "M":
                continue
            out += [(f"conv{li}.w", (3, 3, cin, v)),
                    (f"conv{li}.gn.g", (v,)), (f"conv{li}.gn.b", (v,))]
            cin = v
            li += 1
        flat = 128 * 4 * 4
        out += [("fc1.w", (flat, 256)), ("fc1.b", (256,)),
                ("head.w", (256, ncls)), ("head.b", (ncls,))]
    else:  # pragma: no cover
        raise ValueError(f"unknown family {family}")
    return out


def param_count(model: str) -> int:
    return sum(int(np.prod(s)) for _, s in spec(model))


def flatten(params: Dict[str, jax.Array], model: str) -> jax.Array:
    return jnp.concatenate([params[n].reshape(-1) for n, _ in spec(model)])


def unflatten(flat: jax.Array, model: str) -> Dict[str, jax.Array]:
    out, off = {}, 0
    for name, shape in spec(model):
        size = int(np.prod(shape))
        out[name] = lax.slice_in_dim(flat, off, off + size).reshape(shape)
        off += size
    return out


def init_params(model: str, seed: int = 42) -> jax.Array:
    """He-initialized flat parameter vector (written to artifacts/*.init.bin)."""
    key = random.PRNGKey(seed)
    chunks = []
    for name, shape in spec(model):
        key, sub = random.split(key)
        if ".gn" in name or name.startswith("final.gn"):
            # GroupNorm gamma -> 1, beta -> 0
            fill = 1.0 if name.endswith(".g") else 0.0
            chunks.append(jnp.full(shape, fill, jnp.float32))
        elif name.endswith(".b"):
            chunks.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = float(np.sqrt(2.0 / max(fan_in, 1)))
            chunks.append(std * random.normal(sub, shape, jnp.float32))
    return jnp.concatenate([c.reshape(-1) for c in chunks])


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _conv(x, w, stride=1):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _maxpool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _groupnorm(x, gamma, beta, groups=_GN_GROUPS, eps=1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * lax.rsqrt(var + eps)
    return xg.reshape(n, h, w, c) * gamma + beta


def _dense(x, w, b):
    """Dense layer on the L1 Pallas matmul kernel."""
    return matmul(x, w) + b


def _forward_mlp(p, x):
    h = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(_dense(h, p["fc1.w"], p["fc1.b"]))
    h = jax.nn.relu(_dense(h, p["fc2.w"], p["fc2.b"]))
    return _dense(h, p["head.w"], p["head.b"])


def _forward_resnet(p, x):
    h = _conv(x, p["stem.w"])
    cin = 16
    for si, (ch, blocks, stride) in enumerate(_RESNET_STAGES):
        for bi in range(blocks):
            pre = f"s{si}.b{bi}"
            st = stride if bi == 0 else 1
            z = _groupnorm(h, p[f"{pre}.gn1.g"], p[f"{pre}.gn1.b"])
            z = jax.nn.relu(z)
            z = _conv(z, p[f"{pre}.conv1.w"], st)
            z = _groupnorm(z, p[f"{pre}.gn2.g"], p[f"{pre}.gn2.b"])
            z = jax.nn.relu(z)
            z = _conv(z, p[f"{pre}.conv2.w"])
            skip = h
            if cin != ch:
                skip = _conv(h, p[f"{pre}.proj.w"], st)
            h = skip + z
            cin = ch
    h = jax.nn.relu(_groupnorm(h, p["final.gn.g"], p["final.gn.b"]))
    h = jnp.mean(h, axis=(1, 2))  # global average pool
    return _dense(h, p["head.w"], p["head.b"])


def _forward_vgg(p, x):
    h = x
    li = 0
    for v in _VGG_CFG:
        if v == "M":
            h = _maxpool2(h)
        else:
            h = _conv(h, p[f"conv{li}.w"])
            h = _groupnorm(h, p[f"conv{li}.gn.g"], p[f"conv{li}.gn.b"])
            h = jax.nn.relu(h)
            li += 1
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(_dense(h, p["fc1.w"], p["fc1.b"]))
    return _dense(h, p["head.w"], p["head.b"])


_FORWARDS = {"mlp": _forward_mlp, "resnet": _forward_resnet, "vgg": _forward_vgg}


def forward(model: str, flat: jax.Array, x: jax.Array) -> jax.Array:
    family, _, _, _ = MODELS[model]
    return _FORWARDS[family](unflatten(flat, model), x)


# ---------------------------------------------------------------------------
# Train / eval / update entry points (lowered by aot.py)
# ---------------------------------------------------------------------------


def _masked_ce(logits, y, mask, ncls):
    logp = jax.nn.log_softmax(logits)
    ce = -jnp.sum(logp * jax.nn.one_hot(y, ncls, dtype=logits.dtype), axis=-1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(ce * mask) / denom


def _masked_topk_correct(logits, y, mask, k):
    # rank of the true class = #logits strictly greater; top-k hit ⇔ rank < k.
    # (avoids lax.top_k: xla_extension 0.5.1's HLO parser rejects the TopK
    # instruction's `largest` attribute emitted by newer jax)
    true_logit = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32), axis=-1)
    rank = jnp.sum((logits > true_logit).astype(jnp.int32), axis=-1)
    hit = (rank < k).astype(jnp.float32)
    return jnp.sum(hit * mask)


def train_step(model: str):
    """(params[d], x[b,32,32,3], y[b] i32, mask[b]) ->
    (loss[], grads[d], top1_correct[], top5_correct[])

    Loss/gradient are masked means over valid samples — the device-local
    g_i of ScaDLES Eqn. 4b; the Rust coordinator owns the r_i weighting.
    """
    _, ncls, _, _ = MODELS[model]

    def fn(flat, x, y, mask):
        def loss_fn(f):
            logits = forward(model, f, x)
            return _masked_ce(logits, y, mask, ncls), logits

        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        top1 = _masked_topk_correct(logits, y, mask, 1)
        top5 = _masked_topk_correct(logits, y, mask, min(5, ncls))
        return loss, grads, top1, top5

    return fn


def eval_step(model: str):
    """(params, x, y, mask) -> (sum_loss[], top1_correct[], top5_correct[])."""
    _, ncls, _, _ = MODELS[model]

    def fn(flat, x, y, mask):
        logits = forward(model, flat, x)
        logp = jax.nn.log_softmax(logits)
        ce = -jnp.sum(logp * jax.nn.one_hot(y, ncls, dtype=logits.dtype), axis=-1)
        return (
            jnp.sum(ce * mask),
            _masked_topk_correct(logits, y, mask, 1),
            _masked_topk_correct(logits, y, mask, min(5, ncls)),
        )

    return fn


def update_step(model: str):
    """(params[d], mom[d], grad[d], lr[]) -> (params'[d], mom'[d]).

    PyTorch-semantics momentum SGD with the paper's per-model weight decay
    (coupled, applied to the gradient): v' = mu v + (g + wd w); w' = w - lr v'.
    """
    _, _, mu, wd = MODELS[model]

    def fn(flat, mom, grad, lr):
        g = grad + wd * flat
        mom_new = mu * mom + g
        return flat - lr * mom_new, mom_new

    return fn
