"""AOT compiler: lower the L2/L1 JAX graphs to HLO-text artifacts.

This is the ONLY place Python touches the pipeline; it runs once at build
time (`make artifacts`) and emits, per model:

  train_step_{model}_b{B}.hlo.txt   for every batch bucket B
  eval_step_{model}_b{Bmax}.hlo.txt (one bucket; eval batches are padded)
  update_{model}.hlo.txt            fused momentum-SGD parameter update
  wagg_{model}_n{N}.hlo.txt         Pallas weighted aggregation, N devices
  topk_{model}.hlo.txt              Pallas top-k mask + compression stats
  {model}.init.bin                  raw little-endian f32 initial params

plus a single `manifest.json` describing shapes/buckets so the Rust
runtime (rust/src/runtime/artifact.rs) can load everything without
reparsing Python.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.topk import topk_mask_stats
from .kernels.wagg import weighted_aggregate

DEFAULT_BUCKETS = [8, 16, 32, 64, 128, 256]
#: wagg/topk artifacts run on gradients padded to a multiple of this, so
#: the Pallas grid gets full-width tiles regardless of the model's exact
#: parameter count (820874 has no divisor between 58 and 4096, which would
#: otherwise force 58-wide tiles — see EXPERIMENTS.md §Perf L1).
PAD_MULTIPLE = 4096
DEFAULT_MODELS = ["mlp_c10", "resnet_tiny_c10", "vgg_tiny_c100"]
DEFAULT_DEVICES = [4, 8, 10, 16, 25]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(out_dir: str, name: str, text: str, manifest_files: dict, kind: str, meta: dict):
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    manifest_files[name] = {"kind": kind, **meta}
    return path


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def lower_model(model: str, buckets, device_counts, out_dir, manifest, verbose=True):
    d = M.param_count(model)
    _, ncls, momentum, wd = M.MODELS[model]
    files = manifest["files"]
    dp = (d + PAD_MULTIPLE - 1) // PAD_MULTIPLE * PAD_MULTIPLE
    entry = {
        "param_count": d,
        "padded_dim": dp,
        "num_classes": ncls,
        "momentum": momentum,
        "weight_decay": wd,
        "buckets": list(buckets),
        "eval_bucket": max(buckets),
        "image": list(M.IMG),
        "spec": [[n, list(s)] for n, s in M.spec(model)],
    }
    manifest["models"][model] = entry

    def log(msg):
        if verbose:
            print(f"[aot] {model}: {msg}", flush=True)

    # --- train steps, one per bucket -------------------------------------
    for b in buckets:
        t0 = time.time()
        lowered = jax.jit(M.train_step(model)).lower(
            f32(d), f32(b, *M.IMG), i32(b), f32(b)
        )
        name = f"train_step_{model}_b{b}.hlo.txt"
        _write(out_dir, name, to_hlo_text(lowered), files, "train_step",
               {"model": model, "bucket": b})
        log(f"train_step b={b} ({time.time() - t0:.1f}s)")

    # --- eval step (max bucket only) --------------------------------------
    eb = max(buckets)
    lowered = jax.jit(M.eval_step(model)).lower(f32(d), f32(eb, *M.IMG), i32(eb), f32(eb))
    _write(out_dir, f"eval_step_{model}_b{eb}.hlo.txt", to_hlo_text(lowered),
           files, "eval_step", {"model": model, "bucket": eb})
    log(f"eval_step b={eb}")

    # --- fused optimizer update -------------------------------------------
    lowered = jax.jit(M.update_step(model)).lower(f32(d), f32(d), f32(d), f32())
    _write(out_dir, f"update_{model}.hlo.txt", to_hlo_text(lowered),
           files, "update", {"model": model})
    log("update")

    # --- weighted aggregation (L1 Pallas), per device-count ---------------
    # padded to PAD_MULTIPLE so the kernels tile at full width
    for n in device_counts:
        lowered = jax.jit(weighted_aggregate).lower(f32(n, dp), f32(n))
        _write(out_dir, f"wagg_{model}_n{n}.hlo.txt", to_hlo_text(lowered),
               files, "wagg", {"model": model, "devices": n, "bucket": dp})
        log(f"wagg n={n} (padded d={dp})")

    # --- top-k mask + stats (L1 Pallas) ------------------------------------
    lowered = jax.jit(topk_mask_stats).lower(f32(dp), f32(1))
    _write(out_dir, f"topk_{model}.hlo.txt", to_hlo_text(lowered),
           files, "topk", {"model": model, "bucket": dp})
    log(f"topk (padded d={dp})")

    # --- initial parameters -------------------------------------------------
    seed = manifest["seed"]
    init = np.asarray(M.init_params(model, seed), dtype="<f4")
    init.tofile(os.path.join(out_dir, f"{model}.init.bin"))
    files[f"{model}.init.bin"] = {"kind": "init", "model": model, "seed": seed}
    log("init params")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    ap.add_argument("--devices", default=",".join(map(str, DEFAULT_DEVICES)),
                    help="device counts to emit wagg artifacts for")
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    models = [m.strip() for m in args.models.split(",") if m.strip()]
    buckets = sorted({int(b) for b in args.buckets.split(",")})
    device_counts = sorted({int(n) for n in args.devices.split(",")})
    for m in models:
        if m not in M.MODELS:
            ap.error(f"unknown model {m}; choices: {sorted(M.MODELS)}")

    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "seed": args.seed,
        "jax_version": jax.__version__,
        "buckets": buckets,
        "device_counts": device_counts,
        "models": {},
        "files": {},
    }
    t0 = time.time()
    for m in models:
        lower_model(m, buckets, device_counts, out_dir, manifest,
                    verbose=not args.quiet)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    if not args.quiet:
        n = len(manifest["files"])
        print(f"[aot] wrote {n} artifacts + manifest.json in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
