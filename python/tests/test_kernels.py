"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes/dtypes; every case asserts allclose against
`compile.kernels.ref`. If these pass, the HLO artifacts embed kernels that
compute exactly what the reference math says.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, matmul_pallas
from compile.kernels.topk import topk_mask_stats
from compile.kernels.wagg import weighted_aggregate

jax.config.update("jax_platform_name", "cpu")

# Keep hypothesis deadlines generous: pallas interpret tracing is slow.
SETTINGS = dict(max_examples=12, deadline=None)


def rnd(key, *shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 3, 8, 16, 100, 128]),
    k=st.sampled_from([1, 7, 64, 200, 512]),
    n=st.sampled_from([1, 10, 100, 128]),
)
def test_matmul_matches_ref(m, k, n):
    x, w = rnd(0, m, k), rnd(1, k, n)
    np.testing.assert_allclose(
        matmul_pallas(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@settings(**SETTINGS)
@given(
    m=st.sampled_from([2, 8, 32]),
    k=st.sampled_from([16, 96]),
    n=st.sampled_from([4, 48]),
)
def test_matmul_gradients_match_ref(m, k, n):
    x, w = rnd(2, m, k), rnd(3, k, n)

    def f_pallas(x, w):
        return jnp.sum(jnp.tanh(matmul(x, w)))

    def f_ref(x, w):
        return jnp.sum(jnp.tanh(ref.matmul_ref(x, w)))

    gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
    rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(gw, rw, rtol=1e-3, atol=1e-4)


def test_matmul_bf16_inputs_accumulate_f32():
    x = rnd(4, 16, 64).astype(jnp.bfloat16)
    w = rnd(5, 64, 8).astype(jnp.bfloat16)
    out = matmul_pallas(x, w)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, ref.matmul_ref(x, w), rtol=2e-2, atol=2e-2)


def test_matmul_rejects_mismatched_shapes():
    with pytest.raises(AssertionError):
        matmul_pallas(rnd(0, 4, 5), rnd(1, 6, 3))


# ---------------------------------------------------------------------------
# weighted aggregation (Eqn. 4b)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 4, 16, 25]),
    d=st.sampled_from([1, 17, 512, 4096, 5000]),
)
def test_wagg_matches_ref(n, d):
    g = rnd(6, n, d)
    r = jax.nn.softmax(rnd(7, n))
    np.testing.assert_allclose(
        weighted_aggregate(g, r), ref.wagg_ref(g, r), rtol=1e-4, atol=1e-5
    )


def test_wagg_zero_weights_drop_devices():
    g = rnd(8, 4, 100)
    r = jnp.array([0.0, 1.0, 0.0, 0.0])
    np.testing.assert_allclose(weighted_aggregate(g, r), g[1], rtol=1e-5, atol=1e-6)


def test_wagg_weights_need_not_sum_to_one():
    g = rnd(9, 3, 64)
    r = jnp.array([2.0, -1.0, 0.5])
    np.testing.assert_allclose(
        weighted_aggregate(g, r), ref.wagg_ref(g, r), rtol=1e-4, atol=1e-5
    )


# ---------------------------------------------------------------------------
# top-k mask + stats
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    d=st.sampled_from([1, 9, 100, 4096, 10000]),
    q=st.sampled_from([0.0, 0.3, 0.9, 1.5]),
)
def test_topk_matches_ref(d, q):
    g = rnd(10, d)
    thresh = jnp.array([q], jnp.float32)
    m, n2, k2, nnz = topk_mask_stats(g, thresh)
    mr, n2r, k2r, nnzr = ref.topk_mask_ref(g, thresh[0])
    np.testing.assert_allclose(m, mr, atol=0)
    np.testing.assert_allclose(n2[0], n2r, rtol=1e-5)
    np.testing.assert_allclose(k2[0], k2r, rtol=1e-5)
    assert nnz[0] == nnzr


def test_topk_extreme_thresholds():
    g = rnd(11, 1000)
    m, n2, k2, nnz = topk_mask_stats(g, jnp.array([jnp.inf], jnp.float32))
    assert nnz[0] == 0 and k2[0] == 0
    np.testing.assert_allclose(m, jnp.zeros_like(g))
    m, n2, k2, nnz = topk_mask_stats(g, jnp.array([0.0], jnp.float32))
    assert nnz[0] == 1000
    np.testing.assert_allclose(k2[0], n2[0], rtol=1e-6)


def test_topk_energy_is_monotone_in_threshold():
    g = rnd(12, 5000)
    energies = []
    for q in [0.0, 0.5, 1.0, 2.0]:
        _, _, k2, _ = topk_mask_stats(g, jnp.array([q], jnp.float32))
        energies.append(float(k2[0]))
    assert energies == sorted(energies, reverse=True)


# ---------------------------------------------------------------------------
# fused optimizer update (mirrors the update artifact)
# ---------------------------------------------------------------------------


def test_sgd_momentum_ref_matches_manual():
    p = jnp.array([1.0, -2.0])
    v = jnp.array([0.1, 0.0])
    g = jnp.array([0.5, 0.5])
    p2, v2 = ref.sgd_momentum_ref(p, v, g, lr=0.1, momentum=0.9, weight_decay=0.01)
    v_hand = 0.9 * v + (g + 0.01 * p)
    np.testing.assert_allclose(v2, v_hand, rtol=1e-6)
    np.testing.assert_allclose(p2, p - 0.1 * v_hand, rtol=1e-6)
