"""AOT pipeline: manifest contents, HLO-text validity, init params."""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def out(tmp_path_factory):
    """Emit a minimal artifact set once for the whole module."""
    d = str(tmp_path_factory.mktemp("artifacts"))
    rc = aot.main([
        "--out-dir", d,
        "--models", "mlp_c10",
        "--buckets", "8,16",
        "--devices", "4",
        "--seed", "7",
        "--quiet",
    ])
    assert rc == 0
    return d


def test_manifest_schema(out):
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == 1
    assert m["buckets"] == [8, 16]
    assert m["device_counts"] == [4]
    mm = m["models"]["mlp_c10"]
    assert mm["param_count"] == M.param_count("mlp_c10")
    assert mm["num_classes"] == 10
    assert mm["eval_bucket"] == 16
    assert [n for n, _ in mm["spec"]] == [n for n, _ in M.spec("mlp_c10")]


def test_expected_files_exist(out):
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    expected = {
        "train_step_mlp_c10_b8.hlo.txt": "train_step",
        "train_step_mlp_c10_b16.hlo.txt": "train_step",
        "eval_step_mlp_c10_b16.hlo.txt": "eval_step",
        "update_mlp_c10.hlo.txt": "update",
        "wagg_mlp_c10_n4.hlo.txt": "wagg",
        "topk_mlp_c10.hlo.txt": "topk",
        "mlp_c10.init.bin": "init",
    }
    for name, kind in expected.items():
        assert name in m["files"], name
        assert m["files"][name]["kind"] == kind
        assert os.path.exists(os.path.join(out, name)), name


def test_hlo_text_is_parsable_hlo(out):
    """The interchange contract: HLO *text* with an ENTRY computation and
    no serialized-proto artifacts (xla_extension 0.5.1 requirement)."""
    path = os.path.join(out, "train_step_mlp_c10_b8.hlo.txt")
    text = open(path).read()
    assert "HloModule" in text.splitlines()[0]
    assert "ENTRY" in text
    # the Pallas matmul kernel lowers to dot ops inside
    assert " dot(" in text or " dot." in text
    # no TopK instruction (rejected by the 0.5.1 parser)
    assert "topk(" not in text


def test_init_params_roundtrip(out):
    d = M.param_count("mlp_c10")
    raw = np.fromfile(os.path.join(out, "mlp_c10.init.bin"), dtype="<f4")
    assert raw.shape == (d,)
    np.testing.assert_allclose(raw, np.asarray(M.init_params("mlp_c10", 7)), rtol=1e-7)


def test_init_seed_changes_params(out):
    a = np.asarray(M.init_params("mlp_c10", 1))
    b = np.asarray(M.init_params("mlp_c10", 2))
    assert not np.allclose(a, b)


def test_unknown_model_rejected(tmp_path):
    with pytest.raises(SystemExit):
        aot.main(["--out-dir", str(tmp_path), "--models", "nonexistent"])


def test_update_artifact_is_small(out):
    """The fused optimizer update must stay a lean elementwise module."""
    size = os.path.getsize(os.path.join(out, "update_mlp_c10.hlo.txt"))
    assert size < 64 * 1024, size
