"""L2 model-zoo correctness: shapes, flatten/unflatten, training entry points."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, *M.IMG))
    y = jnp.arange(8, dtype=jnp.int32) % 10
    mask = jnp.ones(8)
    return x, y, mask


ALL_MODELS = list(M.MODELS)


@pytest.mark.parametrize("name", ALL_MODELS)
def test_spec_matches_param_count(name):
    d = M.param_count(name)
    total = sum(int(np.prod(s)) for _, s in M.spec(name))
    assert d == total
    p = M.init_params(name)
    assert p.shape == (d,) and p.dtype == jnp.float32


@pytest.mark.parametrize("name", ALL_MODELS)
def test_flatten_unflatten_roundtrip(name):
    flat = M.init_params(name, seed=7)
    tree = M.unflatten(flat, name)
    assert set(tree) == {n for n, _ in M.spec(name)}
    np.testing.assert_array_equal(M.flatten(tree, name), flat)


@pytest.mark.parametrize("name", ["mlp_c10", "resnet_tiny_c10", "vgg_tiny_c100"])
def test_forward_shapes_and_finite(name, batch):
    x, _, _ = batch
    _, ncls, _, _ = M.MODELS[name]
    logits = M.forward(name, M.init_params(name), x)
    assert logits.shape == (8, ncls)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("name", ["mlp_c10", "resnet_tiny_c10"])
def test_train_step_outputs(name, batch):
    x, y, mask = batch
    loss, grads, top1, top5 = jax.jit(M.train_step(name))(M.init_params(name), x, y, mask)
    d = M.param_count(name)
    assert grads.shape == (d,)
    assert bool(jnp.isfinite(loss)) and loss > 0
    assert 0 <= float(top1) <= 8 and float(top1) <= float(top5) <= 8
    assert float(jnp.linalg.norm(grads)) > 0


def test_mask_neutralizes_padding(batch):
    """Padded rows must not affect loss or gradients — the batch-bucket
    contract the Rust runtime relies on."""
    x, y, _ = batch
    ts = jax.jit(M.train_step("mlp_c10"))
    p = M.init_params("mlp_c10")
    mask_half = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    # corrupt the masked rows wildly
    x_bad = x.at[4:].set(99.0)
    y_bad = y.at[4:].set(3)
    l1, g1, t1, t5 = ts(p, x, y, mask_half)
    l2, g2, u1, u5 = ts(p, x_bad, y_bad, mask_half)
    np.testing.assert_allclose(l1, l2, rtol=1e-6)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-7)
    assert t1 == u1 and t5 == u5


def test_loss_is_mean_over_valid_only(batch):
    x, y, _ = batch
    ts = jax.jit(M.train_step("mlp_c10"))
    p = M.init_params("mlp_c10")
    full, _, _, _ = ts(p, x, y, jnp.ones(8))
    # same data duplicated into half the slots → same mean loss
    half_mask = jnp.array([1, 1, 1, 1, 0, 0, 0, 0], jnp.float32)
    half, _, _, _ = ts(p, x, y, half_mask)
    assert bool(jnp.isfinite(half))
    # mean over 4 of the same distribution: close, not equal
    assert abs(float(full) - float(half)) < 1.0


def test_empty_mask_is_safe(batch):
    x, y, _ = batch
    loss, grads, t1, t5 = jax.jit(M.train_step("mlp_c10"))(
        M.init_params("mlp_c10"), x, y, jnp.zeros(8)
    )
    assert float(loss) == 0.0
    assert float(t1) == 0.0 and float(t5) == 0.0
    np.testing.assert_allclose(grads, jnp.zeros_like(grads), atol=1e-8)


def test_update_step_matches_reference(batch):
    from compile.kernels import ref

    name = "vgg_tiny_c100"
    _, _, mu, wd = M.MODELS[name]
    d = M.param_count(name)
    key = jax.random.PRNGKey(3)
    p = jax.random.normal(key, (d,)) * 0.01
    v = jnp.zeros(d)
    g = jax.random.normal(jax.random.PRNGKey(4), (d,)) * 0.1
    p2, v2 = jax.jit(M.update_step(name))(p, v, g, jnp.float32(0.05))
    pr, vr = ref.sgd_momentum_ref(p, v, g, 0.05, mu, wd)
    np.testing.assert_allclose(p2, pr, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(v2, vr, rtol=1e-5, atol=1e-7)


def test_sgd_reduces_loss_quickly():
    """Ten steps of momentum SGD on one batch must overfit it."""
    name = "mlp_c10"
    ts = jax.jit(M.train_step(name))
    us = jax.jit(M.update_step(name))
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (16, *M.IMG))
    y = jnp.arange(16, dtype=jnp.int32) % 10
    mask = jnp.ones(16)
    p = M.init_params(name)
    v = jnp.zeros_like(p)
    l0, *_ = ts(p, x, y, mask)
    for _ in range(10):
        _, g, _, _ = ts(p, x, y, mask)
        p, v = us(p, v, g, jnp.float32(0.1))
    l1, *_ = ts(p, x, y, mask)
    assert float(l1) < float(l0) * 0.5, (l0, l1)


def test_top5_counts_rank_correctly():
    name = "mlp_c10"
    # craft logits via a linear probe: use the internal helper directly
    from compile.model import _masked_topk_correct

    logits = jnp.array([[5.0, 4.0, 3.0, 2.0, 1.0, 0.0, -1.0, -2.0, -3.0, -4.0]])
    mask = jnp.ones(1)
    assert float(_masked_topk_correct(logits, jnp.array([0]), mask, 1)) == 1.0
    assert float(_masked_topk_correct(logits, jnp.array([4]), mask, 5)) == 1.0
    assert float(_masked_topk_correct(logits, jnp.array([5]), mask, 5)) == 0.0
    _ = name
